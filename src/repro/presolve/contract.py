"""Kernel assembly and contraction-derived instances.

:func:`kernelize` runs the reduction rules (``rules.py``) and packages
the survivors into a :class:`Kernel`: a smaller ``STInstance`` over the
kernel nodes, a ``vertex_map`` relating original vertices to kernel
vertices (or to a terminal side / an eliminated slot), and the journal
needed to lift solutions back (``lift.py``).

:func:`derive_instance` / ``Problem.derive`` / ``Problem.contract`` are
the general contraction API: given any vertex grouping they build the
merged instance plus edge/weight projection maps, so callers (e.g. the
Gomory-Hu builder in ``cuttree``) can pose cut problems on contracted
topologies and map results back.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from ..graphs.structures import EdgeList, STInstance, canonicalize_edges
from .rules import (IN_BASE, IN_DROPPED, RULES, Reduction, reduce_instance)

# vertex_map sentinel codes for non-surviving vertices
MERGED_SOURCE = -1
MERGED_SINK = -2
ELIMINATED = -3   # removed by a degree-2 series merge; side from journal

# WeightMap kinds: where an original weight entry's value ends up in the
# kernel.  Entries whose kind is K_EDGE / K_CS / K_CT / K_BASE / K_DROP
# contribute *additively* to the indexed kernel quantity, so a pure value
# change there patches through; K_POISON fed a value-dependent rule
# decision and K_ABSENT is a terminal entry that was <= 0 at kernelize
# time (no pseudo-edge existed) — changes to either force a re-kernelize.
K_EDGE = 0     # idx-th kernel graph edge weight
K_CS = 1       # kernel source weight of node idx
K_CT = 2       # kernel sink weight of node idx
K_BASE = 3     # folded into Kernel.base
K_DROP = 4     # self-loop after contraction — value-irrelevant
K_POISON = 5
K_ABSENT = 6


@dataclasses.dataclass(frozen=True)
class WeightMap:
    """Additive provenance of original weights in a kernel.

    ``edge_kind``/``edge_idx`` cover the m original graph edges;
    ``cs_*``/``ct_*`` cover the n terminal weight entries.  See the
    ``K_*`` kind codes above.  Built by :func:`kernelize` (``track=True``)
    and consumed by :func:`patch_kernel`.
    """

    edge_kind: np.ndarray   # int8[m]
    edge_idx: np.ndarray    # int64[m]
    cs_kind: np.ndarray     # int8[n]
    cs_idx: np.ndarray      # int64[n]
    ct_kind: np.ndarray     # int8[n]
    ct_idx: np.ndarray      # int64[n]


@dataclasses.dataclass(frozen=True)
class Kernel:
    """Exact kernel of an s-t min-cut instance.

    ``instance`` is the reduced problem over ``kernel_n`` nodes (with the
    reduced terminal weights baked in); solving it and adding ``base``
    gives the original min-cut value.  ``vertex_map[i]`` is the kernel id
    of original vertex i, or ``MERGED_SOURCE`` / ``MERGED_SINK`` /
    ``ELIMINATED``.  A trivial kernel (``kernel_n == 0``) means the cut
    is fully decided by reductions — including the s-t disconnected
    case, where ``base == 0``.
    """

    original: STInstance
    instance: Optional[STInstance]   # None iff trivial
    vertex_map: np.ndarray           # int64[n]
    base: float
    st_connected: bool
    journal: np.ndarray              # float64[k, 5] (u, a, b, w_ua, w_ub)
    parent: np.ndarray               # int64[n+2] fully compressed
    removed: np.ndarray              # bool[n+2]
    kernel_of_root: np.ndarray       # int64[n+2]: kernel id per surviving root, else -1
    stats: Dict[str, int]
    wmap: Optional["WeightMap"] = None   # set when kernelized with track=True

    @property
    def n(self) -> int:
        return self.original.n

    @property
    def kernel_n(self) -> int:
        return 0 if self.instance is None else self.instance.n

    @property
    def kernel_m(self) -> int:
        return 0 if self.instance is None else self.instance.graph.m

    @property
    def trivial(self) -> bool:
        return self.instance is None

    @property
    def node_reduction(self) -> float:
        """Original/kernel node-count ratio (inf for trivial kernels)."""
        kn = self.kernel_n
        return float("inf") if kn == 0 else self.n / kn

    @property
    def edge_reduction(self) -> float:
        m = self.original.graph.m
        km = self.kernel_m
        return float("inf") if km == 0 else max(m, 1) / km

    # lifting lives in lift.py; re-exported as methods for ergonomics
    def lift_partition(self, kernel_side: Optional[np.ndarray]) -> np.ndarray:
        from .lift import lift_partition
        return lift_partition(self, kernel_side)

    def lift_voltages(self, kernel_v: Optional[np.ndarray],
                      high: float = 1.0, low: float = 0.0) -> np.ndarray:
        from .lift import lift_voltages
        return lift_voltages(self, kernel_v, high=high, low=low)

    def certificate(self, kernel_side: Optional[np.ndarray]) -> Dict[str, float]:
        from .lift import cut_certificate
        return cut_certificate(self, kernel_side)


def _weight_map(red: Reduction, skind: np.ndarray,
                sidx: np.ndarray) -> WeightMap:
    """Compose input->slot provenance with the slot->kernel split."""
    slot = red.input_slot
    kind = np.full(slot.shape[0], K_POISON, dtype=np.int8)
    idx = np.zeros(slot.shape[0], dtype=np.int64)
    live = slot >= 0
    kind[live] = skind[slot[live]]
    idx[live] = sidx[slot[live]]
    kind[slot == IN_DROPPED] = K_DROP
    kind[slot == IN_BASE] = K_BASE
    ns, nt = red.si.shape[0], red.ti.shape[0]
    m = slot.shape[0] - ns - nt
    cs_kind = np.full(red.n, K_ABSENT, dtype=np.int8)
    cs_idx = np.zeros(red.n, dtype=np.int64)
    ct_kind = np.full(red.n, K_ABSENT, dtype=np.int8)
    ct_idx = np.zeros(red.n, dtype=np.int64)
    cs_kind[red.si] = kind[m:m + ns]
    cs_idx[red.si] = idx[m:m + ns]
    ct_kind[red.ti] = kind[m + ns:]
    ct_idx[red.ti] = idx[m + ns:]
    return WeightMap(edge_kind=kind[:m], edge_idx=idx[:m],
                     cs_kind=cs_kind, cs_idx=cs_idx,
                     ct_kind=ct_kind, ct_idx=ct_idx)


def _assemble(instance: STInstance, red: Reduction) -> Kernel:
    n = red.n
    S, T = n, n + 1
    parent = red.parent
    ids = np.arange(n + 2)
    is_root = parent == ids
    # Surviving candidate roots: non-terminal, unremoved union-find roots.
    surv = is_root & (ids < n) & ~red.removed
    # Isolated survivors (no incident edge at all, not even a terminal
    # edge) are degree-0: cut-neutral, merged into the source side.
    touched = np.zeros(n + 2, dtype=bool)
    touched[red.eu] = True
    touched[red.ev] = True
    isolated = surv & ~touched
    n_iso = int(isolated.sum())
    if n_iso:
        parent = parent.copy()
        parent[isolated] = S
        surv = surv & ~isolated
    kernel_of_root = np.full(n + 2, -1, dtype=np.int64)
    roots = np.nonzero(surv)[0]
    kn = int(roots.size)
    kernel_of_root[roots] = np.arange(kn)

    stats = dict(red.stats)
    stats["degree0"] = n_iso
    stats["kernel_n"] = kn

    vm = np.empty(n, dtype=np.int64)
    r = parent[:n]
    vm[:] = kernel_of_root[r]
    vm[r == S] = MERGED_SOURCE
    vm[r == T] = MERGED_SINK
    vm[red.removed[r]] = ELIMINATED

    if kn == 0:
        wmap = None
        if red.input_slot is not None:
            # No kernel slots exist; any still-live slot (impossible in
            # practice once every non-terminal root is merged) maps to
            # poison, sentinel entries keep their additive meaning.
            wmap = _weight_map(
                red, np.full(red.eu.shape[0], K_POISON, dtype=np.int8),
                np.zeros(red.eu.shape[0], dtype=np.int64))
        return Kernel(original=instance, instance=None, vertex_map=vm,
                      base=red.base, st_connected=red.st_connected,
                      journal=red.journal, parent=parent,
                      removed=red.removed, kernel_of_root=kernel_of_root,
                      stats=stats, wmap=wmap)

    # Split surviving canonical edges into kernel edges / terminal weights.
    # Canonical orientation is lo < hi, so a terminal endpoint is always
    # ``ev`` (S = n, T = n + 1 are the largest ids) and S-T edges were
    # already folded into ``base``.
    c_s = np.zeros(kn)
    c_t = np.zeros(kn)
    to_s = red.ev == S
    to_t = red.ev == T
    plain = ~(to_s | to_t)
    np.add.at(c_s, kernel_of_root[red.eu[to_s]], red.ew[to_s])
    np.add.at(c_t, kernel_of_root[red.eu[to_t]], red.ew[to_t])
    ku = kernel_of_root[red.eu[plain]]
    kv = kernel_of_root[red.ev[plain]]
    kw = red.ew[plain]
    g = EdgeList(src=ku.astype(np.int32), dst=kv.astype(np.int32),
                 weight=kw.astype(np.float64), n=kn)
    kinst = STInstance(graph=g, s_weight=c_s, t_weight=c_t)
    stats["kernel_m"] = g.m
    wmap = None
    if red.input_slot is not None:
        n_slots = red.eu.shape[0]
        skind = np.empty(n_slots, dtype=np.int8)
        sidx = np.empty(n_slots, dtype=np.int64)
        skind[plain] = K_EDGE
        sidx[plain] = np.arange(int(plain.sum()), dtype=np.int64)
        skind[to_s] = K_CS
        sidx[to_s] = kernel_of_root[red.eu[to_s]]
        skind[to_t] = K_CT
        sidx[to_t] = kernel_of_root[red.eu[to_t]]
        wmap = _weight_map(red, skind, sidx)
    return Kernel(original=instance, instance=kinst, vertex_map=vm,
                  base=red.base, st_connected=red.st_connected,
                  journal=red.journal, parent=parent, removed=red.removed,
                  kernel_of_root=kernel_of_root, stats=stats, wmap=wmap)


def kernelize(instance: STInstance,
              c: Optional[np.ndarray] = None,
              c_s: Optional[np.ndarray] = None,
              c_t: Optional[np.ndarray] = None,
              rules: Sequence[str] = RULES,
              max_cycles: int = 200,
              track: bool = True) -> Kernel:
    """Reduce ``instance`` (optionally with override weights) to an exact
    kernel.  The kernel preserves the min s-t cut value exactly:
    ``min_cut(kernel) + base == min_cut(original)``.

    ``track=True`` (default) additionally records a :class:`WeightMap`
    on the kernel so that later weight drift can be applied through
    :func:`patch_kernel` without re-running the reduction fixpoint; the
    tracking overhead is a few extra int64 arrays per pass."""
    if c is not None or c_s is not None or c_t is not None:
        # Bake the overrides into the instance the Kernel keeps as
        # "original": lifting and certificates must be evaluated against
        # the weights the reductions actually saw.
        g = instance.graph
        instance = STInstance(
            graph=EdgeList(
                src=g.src, dst=g.dst,
                weight=np.asarray(g.weight if c is None else c,
                                  dtype=np.float64), n=g.n),
            s_weight=np.asarray(instance.s_weight if c_s is None else c_s,
                                dtype=np.float64),
            t_weight=np.asarray(instance.t_weight if c_t is None else c_t,
                                dtype=np.float64))
    from repro.obs import trace
    from repro.obs.metrics import get_registry
    with trace.span("presolve.kernelize", n=instance.n,
                    m=instance.graph.m) as sp:
        red = reduce_instance(instance, rules=rules, max_cycles=max_cycles,
                              track=track)
        kernel = _assemble(instance, red)
        sp.set(kernel_n=kernel.stats.get("kernel_n"),
               kernel_m=kernel.stats.get("kernel_m", 0),
               cycles=kernel.stats.get("cycles"))
    reg = get_registry()
    reg.counter("presolve_kernelize_total").inc()
    reg.counter("presolve_nodes_in_total").inc(instance.n)
    reg.counter("presolve_kernel_nodes_total").inc(
        kernel.stats.get("kernel_n", 0))
    if kernel.trivial:
        reg.counter("presolve_trivial_total").inc()
    return kernel


def patch_kernel(kernel: Kernel,
                 old: Tuple[np.ndarray, np.ndarray, np.ndarray],
                 new: Tuple[np.ndarray, np.ndarray, np.ndarray]
                 ) -> Optional[Kernel]:
    """Revalidate ``kernel`` (built under ``old = (c, c_s, c_t)``) against
    ``new`` weights and return a patched exact kernel, or ``None`` when
    the drift could have changed a reduction decision.

    Soundness rests on two observations.  First, stopping the fixpoint
    early is always exact, so the patched kernel need not match what a
    fresh ``kernelize(new)`` would produce — only the *applied*
    reductions must remain valid.  Second, every applied reduction is
    either purely structural (components, degree-0/1 — valid for any
    nonnegative weights on the same topology) or value-dependent exactly
    on the inputs the tracker poisoned (degree-2 min + journal side,
    heavy-edge condition, terminal cancellation).  Hence a diff patches
    through iff no changed entry is ``K_POISON``, no changed terminal
    entry crosses the support boundary (``K_ABSENT`` becoming positive,
    or a tracked pseudo-edge dropping to zero — either would change the
    terminal edge set the rules saw), and no new weight is negative.
    Everything else applies additively via the :class:`WeightMap`.

    The certificate stays honest automatically: the patched kernel's
    ``original`` carries the new weights, so ``cut_certificate``
    recomputes the lifted cut against them on every solve.
    """
    wm = kernel.wmap
    if wm is None:
        return None
    c_o, cs_o, ct_o = (np.asarray(a, dtype=np.float64) for a in old)
    c_n, cs_n, ct_n = (np.asarray(a, dtype=np.float64) for a in new)
    if (c_o.shape != c_n.shape or cs_o.shape != cs_n.shape
            or ct_o.shape != ct_n.shape
            or c_n.shape[0] != wm.edge_kind.shape[0]
            or cs_n.shape[0] != wm.cs_kind.shape[0]):
        return None
    if kernel.instance is not None:
        kw = np.array(kernel.instance.graph.weight, dtype=np.float64)
        kcs = np.array(kernel.instance.s_weight, dtype=np.float64)
        kct = np.array(kernel.instance.t_weight, dtype=np.float64)
    else:
        kw = kcs = kct = None
    base = float(kernel.base)

    def apply(kind, idx, o, nv, terminal):
        nonlocal base
        chg = np.flatnonzero(o != nv)
        if chg.size == 0:
            return True
        if np.any(nv[chg] < 0):
            return False
        k = kind[chg]
        if np.any(k == K_POISON) or np.any(k == K_ABSENT):
            return False
        if terminal and np.any(nv[chg] <= 0):
            # A tracked pseudo-edge dropping to zero shrinks the terminal
            # edge set the rules reasoned over; re-kernelize.  (Graph
            # edges participate in the reduction regardless of weight,
            # so they have no such support boundary.)
            return False
        d = (nv - o)[chg]
        for code, tgt in ((K_EDGE, kw), (K_CS, kcs), (K_CT, kct)):
            sel = k == code
            if sel.any():
                if tgt is None:
                    return False
                np.add.at(tgt, idx[chg[sel]], d[sel])
        b = k == K_BASE
        if b.any():
            base += float(d[b].sum())
        return True

    if not (apply(wm.edge_kind, wm.edge_idx, c_o, c_n, False)
            and apply(wm.cs_kind, wm.cs_idx, cs_o, cs_n, True)
            and apply(wm.ct_kind, wm.ct_idx, ct_o, ct_n, True)):
        return None
    og = kernel.original.graph
    original = STInstance(
        graph=EdgeList(src=og.src, dst=og.dst, weight=c_n, n=og.n),
        s_weight=cs_n, t_weight=ct_n)
    kinst = kernel.instance
    if kinst is not None:
        kinst = STInstance(
            graph=EdgeList(src=kinst.graph.src, dst=kinst.graph.dst,
                           weight=kw, n=kinst.graph.n),
            s_weight=kcs, t_weight=kct)
    stats = dict(kernel.stats)
    stats["patched"] = stats.get("patched", 0) + 1
    return dataclasses.replace(kernel, original=original, instance=kinst,
                               base=base, stats=stats)


# ---------------------------------------------------------------------------
# General contraction-derived instances (Gomory-Hu building block)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DerivedInstance:
    """A contracted instance plus the maps to project/lift.

    ``vertex_map[i]`` is the contracted id of original node i (always
    >= 0 here — plain contraction never eliminates nodes).  ``edge_map``
    sends each original edge to its contracted slot (-1 if it became a
    self-loop).  ``project_weights`` pushes fresh per-edge weights onto
    the contracted topology; ``lift_partition`` pulls a side assignment
    back to the original vertices.
    """

    instance: STInstance
    vertex_map: np.ndarray
    edge_map: np.ndarray

    def project_weights(self, c: np.ndarray) -> np.ndarray:
        out = np.zeros(self.instance.graph.m)
        ok = self.edge_map >= 0
        np.add.at(out, self.edge_map[ok], np.asarray(c, dtype=np.float64)[ok])
        return out

    def lift_partition(self, side: np.ndarray) -> np.ndarray:
        return np.asarray(side)[self.vertex_map]


def derive_instance(instance: STInstance, vertex_map: np.ndarray) -> DerivedInstance:
    """Contract ``instance`` by ``vertex_map`` (int64[n] -> [0, k)).

    Parallel edges merge by summation, self-loops drop, and terminal
    weights are segment-summed per group — the exact contraction
    semantics for cuts (all merged nodes are forced to one side)."""
    vm = np.asarray(vertex_map, dtype=np.int64)
    if vm.shape != (instance.n,):
        raise ValueError(f"vertex_map must have shape ({instance.n},), got {vm.shape}")
    if vm.min() < 0:
        raise ValueError("vertex_map entries must be >= 0")
    k = int(vm.max()) + 1
    g = instance.graph
    lo, hi, w, emap = canonicalize_edges(
        vm[np.asarray(g.src)], vm[np.asarray(g.dst)], g.weight, k,
        merge="sum", return_map=True)
    c_s = np.zeros(k)
    c_t = np.zeros(k)
    np.add.at(c_s, vm, np.asarray(instance.s_weight, dtype=np.float64))
    np.add.at(c_t, vm, np.asarray(instance.t_weight, dtype=np.float64))
    cg = EdgeList(src=lo.astype(np.int32), dst=hi.astype(np.int32),
                  weight=w, n=k)
    return DerivedInstance(
        instance=STInstance(graph=cg, s_weight=c_s, t_weight=c_t),
        vertex_map=vm, edge_map=emap)


def contraction_map(n: int, groups: Sequence[Sequence[int]]) -> np.ndarray:
    """Build a vertex_map merging each group into one supernode.

    Ungrouped vertices keep distinct ids; ids are compacted to [0, k).
    The supernode of ``groups[j]`` is the id of its smallest member
    after compaction (query via ``vertex_map[groups[j][0]]``)."""
    vm = np.arange(n, dtype=np.int64)
    for grp in groups:
        grp = np.asarray(list(grp), dtype=np.int64)
        if grp.size == 0:
            continue
        vm[grp] = int(grp.min())
    # compact
    uniq, inv = np.unique(vm, return_inverse=True)
    return inv.astype(np.int64)
