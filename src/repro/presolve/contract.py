"""Kernel assembly and contraction-derived instances.

:func:`kernelize` runs the reduction rules (``rules.py``) and packages
the survivors into a :class:`Kernel`: a smaller ``STInstance`` over the
kernel nodes, a ``vertex_map`` relating original vertices to kernel
vertices (or to a terminal side / an eliminated slot), and the journal
needed to lift solutions back (``lift.py``).

:func:`derive_instance` / ``Problem.derive`` / ``Problem.contract`` are
the general contraction API: given any vertex grouping they build the
merged instance plus edge/weight projection maps, so callers (e.g. the
Gomory-Hu builder in ``cuttree``) can pose cut problems on contracted
topologies and map results back.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from ..graphs.structures import EdgeList, STInstance, canonicalize_edges
from .rules import RULES, Reduction, reduce_instance

# vertex_map sentinel codes for non-surviving vertices
MERGED_SOURCE = -1
MERGED_SINK = -2
ELIMINATED = -3   # removed by a degree-2 series merge; side from journal


@dataclasses.dataclass(frozen=True)
class Kernel:
    """Exact kernel of an s-t min-cut instance.

    ``instance`` is the reduced problem over ``kernel_n`` nodes (with the
    reduced terminal weights baked in); solving it and adding ``base``
    gives the original min-cut value.  ``vertex_map[i]`` is the kernel id
    of original vertex i, or ``MERGED_SOURCE`` / ``MERGED_SINK`` /
    ``ELIMINATED``.  A trivial kernel (``kernel_n == 0``) means the cut
    is fully decided by reductions — including the s-t disconnected
    case, where ``base == 0``.
    """

    original: STInstance
    instance: Optional[STInstance]   # None iff trivial
    vertex_map: np.ndarray           # int64[n]
    base: float
    st_connected: bool
    journal: np.ndarray              # float64[k, 5] (u, a, b, w_ua, w_ub)
    parent: np.ndarray               # int64[n+2] fully compressed
    removed: np.ndarray              # bool[n+2]
    kernel_of_root: np.ndarray       # int64[n+2]: kernel id per surviving root, else -1
    stats: Dict[str, int]

    @property
    def n(self) -> int:
        return self.original.n

    @property
    def kernel_n(self) -> int:
        return 0 if self.instance is None else self.instance.n

    @property
    def kernel_m(self) -> int:
        return 0 if self.instance is None else self.instance.graph.m

    @property
    def trivial(self) -> bool:
        return self.instance is None

    @property
    def node_reduction(self) -> float:
        """Original/kernel node-count ratio (inf for trivial kernels)."""
        kn = self.kernel_n
        return float("inf") if kn == 0 else self.n / kn

    @property
    def edge_reduction(self) -> float:
        m = self.original.graph.m
        km = self.kernel_m
        return float("inf") if km == 0 else max(m, 1) / km

    # lifting lives in lift.py; re-exported as methods for ergonomics
    def lift_partition(self, kernel_side: Optional[np.ndarray]) -> np.ndarray:
        from .lift import lift_partition
        return lift_partition(self, kernel_side)

    def lift_voltages(self, kernel_v: Optional[np.ndarray],
                      high: float = 1.0, low: float = 0.0) -> np.ndarray:
        from .lift import lift_voltages
        return lift_voltages(self, kernel_v, high=high, low=low)

    def certificate(self, kernel_side: Optional[np.ndarray]) -> Dict[str, float]:
        from .lift import cut_certificate
        return cut_certificate(self, kernel_side)


def _assemble(instance: STInstance, red: Reduction) -> Kernel:
    n = red.n
    S, T = n, n + 1
    parent = red.parent
    ids = np.arange(n + 2)
    is_root = parent == ids
    # Surviving candidate roots: non-terminal, unremoved union-find roots.
    surv = is_root & (ids < n) & ~red.removed
    # Isolated survivors (no incident edge at all, not even a terminal
    # edge) are degree-0: cut-neutral, merged into the source side.
    touched = np.zeros(n + 2, dtype=bool)
    touched[red.eu] = True
    touched[red.ev] = True
    isolated = surv & ~touched
    n_iso = int(isolated.sum())
    if n_iso:
        parent = parent.copy()
        parent[isolated] = S
        surv = surv & ~isolated
    kernel_of_root = np.full(n + 2, -1, dtype=np.int64)
    roots = np.nonzero(surv)[0]
    kn = int(roots.size)
    kernel_of_root[roots] = np.arange(kn)

    stats = dict(red.stats)
    stats["degree0"] = n_iso
    stats["kernel_n"] = kn

    vm = np.empty(n, dtype=np.int64)
    r = parent[:n]
    vm[:] = kernel_of_root[r]
    vm[r == S] = MERGED_SOURCE
    vm[r == T] = MERGED_SINK
    vm[red.removed[r]] = ELIMINATED

    if kn == 0:
        return Kernel(original=instance, instance=None, vertex_map=vm,
                      base=red.base, st_connected=red.st_connected,
                      journal=red.journal, parent=parent,
                      removed=red.removed, kernel_of_root=kernel_of_root,
                      stats=stats)

    # Split surviving canonical edges into kernel edges / terminal weights.
    # Canonical orientation is lo < hi, so a terminal endpoint is always
    # ``ev`` (S = n, T = n + 1 are the largest ids) and S-T edges were
    # already folded into ``base``.
    c_s = np.zeros(kn)
    c_t = np.zeros(kn)
    to_s = red.ev == S
    to_t = red.ev == T
    plain = ~(to_s | to_t)
    np.add.at(c_s, kernel_of_root[red.eu[to_s]], red.ew[to_s])
    np.add.at(c_t, kernel_of_root[red.eu[to_t]], red.ew[to_t])
    ku = kernel_of_root[red.eu[plain]]
    kv = kernel_of_root[red.ev[plain]]
    kw = red.ew[plain]
    g = EdgeList(src=ku.astype(np.int32), dst=kv.astype(np.int32),
                 weight=kw.astype(np.float64), n=kn)
    kinst = STInstance(graph=g, s_weight=c_s, t_weight=c_t)
    stats["kernel_m"] = g.m
    return Kernel(original=instance, instance=kinst, vertex_map=vm,
                  base=red.base, st_connected=red.st_connected,
                  journal=red.journal, parent=parent, removed=red.removed,
                  kernel_of_root=kernel_of_root, stats=stats)


def kernelize(instance: STInstance,
              c: Optional[np.ndarray] = None,
              c_s: Optional[np.ndarray] = None,
              c_t: Optional[np.ndarray] = None,
              rules: Sequence[str] = RULES,
              max_cycles: int = 200) -> Kernel:
    """Reduce ``instance`` (optionally with override weights) to an exact
    kernel.  The kernel preserves the min s-t cut value exactly:
    ``min_cut(kernel) + base == min_cut(original)``."""
    if c is not None or c_s is not None or c_t is not None:
        # Bake the overrides into the instance the Kernel keeps as
        # "original": lifting and certificates must be evaluated against
        # the weights the reductions actually saw.
        g = instance.graph
        instance = STInstance(
            graph=EdgeList(
                src=g.src, dst=g.dst,
                weight=np.asarray(g.weight if c is None else c,
                                  dtype=np.float64), n=g.n),
            s_weight=np.asarray(instance.s_weight if c_s is None else c_s,
                                dtype=np.float64),
            t_weight=np.asarray(instance.t_weight if c_t is None else c_t,
                                dtype=np.float64))
    from repro.obs import trace
    from repro.obs.metrics import get_registry
    with trace.span("presolve.kernelize", n=instance.n,
                    m=instance.graph.m) as sp:
        red = reduce_instance(instance, rules=rules, max_cycles=max_cycles)
        kernel = _assemble(instance, red)
        sp.set(kernel_n=kernel.stats.get("kernel_n"),
               kernel_m=kernel.stats.get("kernel_m", 0),
               cycles=kernel.stats.get("cycles"))
    reg = get_registry()
    reg.counter("presolve_kernelize_total").inc()
    reg.counter("presolve_nodes_in_total").inc(instance.n)
    reg.counter("presolve_kernel_nodes_total").inc(
        kernel.stats.get("kernel_n", 0))
    if kernel.trivial:
        reg.counter("presolve_trivial_total").inc()
    return kernel


# ---------------------------------------------------------------------------
# General contraction-derived instances (Gomory-Hu building block)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DerivedInstance:
    """A contracted instance plus the maps to project/lift.

    ``vertex_map[i]`` is the contracted id of original node i (always
    >= 0 here — plain contraction never eliminates nodes).  ``edge_map``
    sends each original edge to its contracted slot (-1 if it became a
    self-loop).  ``project_weights`` pushes fresh per-edge weights onto
    the contracted topology; ``lift_partition`` pulls a side assignment
    back to the original vertices.
    """

    instance: STInstance
    vertex_map: np.ndarray
    edge_map: np.ndarray

    def project_weights(self, c: np.ndarray) -> np.ndarray:
        out = np.zeros(self.instance.graph.m)
        ok = self.edge_map >= 0
        np.add.at(out, self.edge_map[ok], np.asarray(c, dtype=np.float64)[ok])
        return out

    def lift_partition(self, side: np.ndarray) -> np.ndarray:
        return np.asarray(side)[self.vertex_map]


def derive_instance(instance: STInstance, vertex_map: np.ndarray) -> DerivedInstance:
    """Contract ``instance`` by ``vertex_map`` (int64[n] -> [0, k)).

    Parallel edges merge by summation, self-loops drop, and terminal
    weights are segment-summed per group — the exact contraction
    semantics for cuts (all merged nodes are forced to one side)."""
    vm = np.asarray(vertex_map, dtype=np.int64)
    if vm.shape != (instance.n,):
        raise ValueError(f"vertex_map must have shape ({instance.n},), got {vm.shape}")
    if vm.min() < 0:
        raise ValueError("vertex_map entries must be >= 0")
    k = int(vm.max()) + 1
    g = instance.graph
    lo, hi, w, emap = canonicalize_edges(
        vm[np.asarray(g.src)], vm[np.asarray(g.dst)], g.weight, k,
        merge="sum", return_map=True)
    c_s = np.zeros(k)
    c_t = np.zeros(k)
    np.add.at(c_s, vm, np.asarray(instance.s_weight, dtype=np.float64))
    np.add.at(c_t, vm, np.asarray(instance.t_weight, dtype=np.float64))
    cg = EdgeList(src=lo.astype(np.int32), dst=hi.astype(np.int32),
                  weight=w, n=k)
    return DerivedInstance(
        instance=STInstance(graph=cg, s_weight=c_s, t_weight=c_t),
        vertex_map=vm, edge_map=emap)


def contraction_map(n: int, groups: Sequence[Sequence[int]]) -> np.ndarray:
    """Build a vertex_map merging each group into one supernode.

    Ungrouped vertices keep distinct ids; ids are compacted to [0, k).
    The supernode of ``groups[j]`` is the id of its smallest member
    after compaction (query via ``vertex_map[groups[j][0]]``)."""
    vm = np.arange(n, dtype=np.int64)
    for grp in groups:
        grp = np.asarray(list(grp), dtype=np.int64)
        if grp.size == 0:
            continue
        vm[grp] = int(grp.min())
    # compact
    uniq, inv = np.unique(vm, return_inverse=True)
    return inv.astype(np.int64)
