"""Lift kernel solutions back to the original vertex set.

Three lift targets:

* partitions (``lift_partition``) — boolean source-side indicators.
  Union-find-merged vertices inherit their root's side; terminal-merged
  vertices take the terminal's side; degree-2-eliminated vertices are
  filled by replaying the elimination journal *in reverse*: a node
  eliminated with incident weights (w_ua, w_ub) sits with the heavier
  neighbour (exactness argument in docs/API.md).
* voltages (``lift_voltages``) — same resolution order with float
  values; terminal-merged nodes pin to ``high``/``low`` so downstream
  sweep rounding still sees them on the correct extreme.
* certificates (``cut_certificate``) — recompute the lifted partition's
  cut value on the *original* instance and check it equals the kernel
  cut value plus the constant ``base``.  This is the end-to-end
  exactness witness: reductions cannot have changed the cut.

Journal replay order matters: an entry (u, a, b, ...) references nodes
that were alive when u was eliminated, so any later merge/elimination of
a or b appears *after* u's entry.  Replaying in reverse therefore
resolves a and b through the final union-find and already-filled journal
sides.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np


def _root_values(kernel, kernel_vals: Optional[np.ndarray],
                 s_val, t_val, dtype) -> np.ndarray:
    """Per-root value array over all n+2 ids, journal-replayed.

    ``kernel_vals`` maps kernel ids to values (None iff trivial kernel).
    """
    n = kernel.n
    S, T = n, n + 1
    parent = kernel.parent
    vals = np.zeros(n + 2, dtype=dtype)
    vals[S] = s_val
    vals[T] = t_val
    surv = kernel.kernel_of_root >= 0
    if kernel.kernel_n:
        if kernel_vals is None:
            raise ValueError("kernel solution required for a nontrivial kernel")
        kv = np.asarray(kernel_vals)
        if kv.shape[0] != kernel.kernel_n:
            raise ValueError(f"expected {kernel.kernel_n} kernel values, got {kv.shape[0]}")
        vals[surv] = kv[kernel.kernel_of_root[surv]].astype(dtype)
    # Reverse journal replay fills eliminated roots.  a/b were alive at
    # u's elimination, so their (final) roots are either terminals,
    # kernel survivors, or nodes eliminated *later* — already filled.
    J = kernel.journal
    for row in J[::-1]:
        u, a, b = int(row[0]), int(row[1]), int(row[2])
        wa, wb = float(row[3]), float(row[4])
        pick = a if wa >= wb else b
        vals[u] = vals[parent[pick]]
    return vals


def lift_partition(kernel, kernel_side: Optional[np.ndarray]) -> np.ndarray:
    """Map a kernel source-side indicator to the original n vertices."""
    vals = _root_values(kernel, kernel_side, True, False, bool)
    return vals[kernel.parent[:kernel.n]]


def lift_voltages(kernel, kernel_v: Optional[np.ndarray],
                  high: float = 1.0, low: float = 0.0) -> np.ndarray:
    """Map kernel voltages to the original vertices (source-side merged
    nodes at ``high``, sink-side at ``low``, journal nodes following the
    heavier neighbour — consistent with ``lift_partition`` under any
    threshold rounding)."""
    vals = _root_values(kernel, kernel_v, high, low, np.float64)
    return vals[kernel.parent[:kernel.n]]


def cut_certificate(kernel, kernel_side: Optional[np.ndarray]) -> Dict[str, float]:
    """Exact cut-value certificate for a lifted partition.

    Returns the kernel-side cut value (+ base), the recomputed original
    cut value of the lifted partition, and their relative gap — which
    must be ~0 (float summation order only) for exact reductions.
    """
    in_source = lift_partition(kernel, kernel_side)
    lifted = float(kernel.original.cut_value(in_source))
    if kernel.kernel_n:
        kcut = float(kernel.instance.cut_value(np.asarray(kernel_side, dtype=bool)))
    else:
        kcut = 0.0
    total = kcut + kernel.base
    denom = max(abs(total), abs(lifted), 1.0)
    return {
        "kernel_cut": kcut,
        "base": float(kernel.base),
        "stated_cut": total,
        "lifted_cut": lifted,
        "rel_gap": abs(total - lifted) / denom,
    }
