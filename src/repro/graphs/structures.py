"""Graph containers used across the framework.

Three layouts, mirroring DESIGN.md §2:

* ``EdgeList`` — canonical undirected edge list (each edge stored once with an
  arbitrary orientation ``src -> dst``).  This is the layout the IRLS solver
  consumes: the incidence operator ``C B x`` is a gather over (src, dst) and
  ``Bᵀ y`` is a ``segment_sum`` scatter.
* ``CSR`` — host-side compressed sparse rows, used by the neighbour sampler,
  the exact max-flow oracle and the partitioner.
* ``ELL`` — ELLPACK padded fixed-degree layout, the TPU-native SpMV layout
  (regular gathers; see kernels/ell_spmv.py).

All device-facing containers are plain NamedTuples of arrays so they are
pytree-compatible and can be donated / sharded by pjit.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import numpy as np

try:  # jnp only needed for device paths; numpy paths must import standalone.
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jnp = None


class EdgeList(NamedTuple):
    """Undirected weighted graph as an oriented edge list.

    src, dst : int32[m]   endpoints (arbitrary but fixed orientation)
    weight   : float[m]   positive edge weights c({u,v})
    n        : int        number of nodes (static python int)
    """

    src: np.ndarray
    dst: np.ndarray
    weight: np.ndarray
    n: int

    @property
    def m(self) -> int:
        return int(self.src.shape[0])

    def degrees(self) -> np.ndarray:
        d = np.zeros(self.n, dtype=np.int64)
        np.add.at(d, np.asarray(self.src), 1)
        np.add.at(d, np.asarray(self.dst), 1)
        return d

    def weighted_degrees(self) -> np.ndarray:
        d = np.zeros(self.n, dtype=np.float64)
        np.add.at(d, np.asarray(self.src), np.asarray(self.weight, dtype=np.float64))
        np.add.at(d, np.asarray(self.dst), np.asarray(self.weight, dtype=np.float64))
        return d

    def total_weight(self) -> float:
        return float(np.sum(self.weight))

    def validate(self) -> "EdgeList":
        src = np.asarray(self.src)
        dst = np.asarray(self.dst)
        w = np.asarray(self.weight)
        assert src.shape == dst.shape == w.shape
        assert src.ndim == 1
        assert np.all(w > 0), "edge weights must be positive"
        assert np.all(src != dst), "self loops are not allowed"
        assert src.min(initial=0) >= 0 and dst.min(initial=0) >= 0
        assert max(src.max(initial=-1), dst.max(initial=-1)) < self.n
        return self

    def permute_nodes(self, perm: np.ndarray) -> "EdgeList":
        """Relabel nodes: new_id = perm[old_id]."""
        perm = np.asarray(perm)
        return EdgeList(
            src=perm[np.asarray(self.src)].astype(np.int32),
            dst=perm[np.asarray(self.dst)].astype(np.int32),
            weight=np.asarray(self.weight),
            n=self.n,
        )


@dataclasses.dataclass(frozen=True)
class CSR:
    """Host-side symmetric adjacency in CSR form (both directions stored)."""

    indptr: np.ndarray  # int64[n+1]
    indices: np.ndarray  # int32[2m]
    data: np.ndarray  # float[2m]
    n: int

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    def neighbors(self, u: int) -> Tuple[np.ndarray, np.ndarray]:
        lo, hi = self.indptr[u], self.indptr[u + 1]
        return self.indices[lo:hi], self.data[lo:hi]

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)


class ELL(NamedTuple):
    """ELLPACK padded neighbour layout (TPU-native SpMV).

    cols    : int32[n, k]  neighbour ids, padded with 0 where invalid
    vals    : float[n, k]  off-diagonal values (0 where padded)
    diag    : float[n]     diagonal of the (Laplacian-like) matrix
    """

    cols: np.ndarray
    vals: np.ndarray
    diag: np.ndarray

    @property
    def n(self) -> int:
        return int(self.cols.shape[0])

    @property
    def k(self) -> int:
        return int(self.cols.shape[1])


def canonicalize_edges(src, dst, weight, n: int, merge: str = "sum",
                       return_map: bool = False):
    """THE edge-list canonicalization: orient each edge ``lo < hi``, drop
    self-loops, sort by ``(lo, hi)`` and collapse parallel edges.

    ``merge`` decides how parallel edge weights combine:

    * ``"sum"``   — capacities in parallel add (contraction semantics: the
      partitioner's coarsening, ``Problem.derive``, the presolve kernel)
    * ``"min"``   — series-path semantics (degree-2 eliminations merge the
      replacement edges of parallel paths by ``min`` per path *before*
      summing; rarely wanted directly)
    * ``"first"`` — keep the first occurrence's weight (the generators'
      historical dedup behavior)

    Returns ``(src, dst, weight)`` as ``int64/int64/float64`` arrays — plus,
    when ``return_map``, an ``int64[m_in]`` map from each input edge to its
    output slot (``-1`` for dropped self-loops), which is what weight
    projection onto a contracted topology needs (``w_out = segment-combine
    of w_in over the map``).

    One implementation shared by ``graphs.generators``,
    ``graphs.partition``, ``repro.presolve`` and ``Problem.derive`` — keep
    it the single source of truth for edge canonicalization.
    """
    if merge not in ("sum", "min", "first"):
        raise ValueError(f"unknown merge {merge!r}; known: sum, min, first")
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    w = np.asarray(weight, dtype=np.float64)
    lo = np.minimum(src, dst)
    hi = np.maximum(src, dst)
    keep = lo != hi
    emap = np.full(src.shape[0], -1, dtype=np.int64)
    key = lo[keep] * np.int64(n) + hi[keep]
    uniq, inv = np.unique(key, return_inverse=True)
    k = uniq.shape[0]
    if merge == "sum":
        wout = np.zeros(k, dtype=np.float64)
        np.add.at(wout, inv, w[keep])
    elif merge == "min":
        wout = np.full(k, np.inf, dtype=np.float64)
        np.minimum.at(wout, inv, w[keep])
    else:  # first occurrence (in input order) wins
        wout = np.zeros(k, dtype=np.float64)
        first_seen = np.full(k, src.shape[0], dtype=np.int64)
        np.minimum.at(first_seen, inv, np.nonzero(keep)[0])
        wout = w[first_seen]
    emap[keep] = inv
    out = (uniq // n, uniq % n, wout)
    return out + (emap,) if return_map else out


def edgelist_to_csr(g: EdgeList) -> CSR:
    src = np.asarray(g.src, dtype=np.int64)
    dst = np.asarray(g.dst, dtype=np.int64)
    w = np.asarray(g.weight, dtype=np.float64)
    rows = np.concatenate([src, dst])
    cols = np.concatenate([dst, src])
    vals = np.concatenate([w, w])
    order = np.argsort(rows, kind="stable")
    rows, cols, vals = rows[order], cols[order], vals[order]
    indptr = np.zeros(g.n + 1, dtype=np.int64)
    np.add.at(indptr, rows + 1, 1)
    indptr = np.cumsum(indptr)
    return CSR(indptr=indptr, indices=cols.astype(np.int32), data=vals, n=g.n)


def csr_to_ell(a: CSR, diag: Optional[np.ndarray] = None, k: Optional[int] = None) -> ELL:
    """Pad a CSR adjacency into ELLPACK.  ``diag`` defaults to weighted degree
    (i.e. the Laplacian diagonal)."""
    deg = a.degrees()
    kk = int(k if k is not None else (deg.max() if a.n else 0))
    cols = np.zeros((a.n, kk), dtype=np.int32)
    vals = np.zeros((a.n, kk), dtype=a.data.dtype)
    for u in range(a.n):
        lo, hi = a.indptr[u], a.indptr[u + 1]
        cnt = int(hi - lo)
        if cnt > kk:
            raise ValueError(f"node {u} degree {cnt} exceeds ELL width {kk}")
        cols[u, :cnt] = a.indices[lo:hi]
        vals[u, :cnt] = a.data[lo:hi]
    if diag is None:
        diag = np.zeros(a.n, dtype=np.float64)
        np.add.at(diag, np.repeat(np.arange(a.n), np.diff(a.indptr)), a.data)
    return ELL(cols=cols, vals=vals, diag=np.asarray(diag))


def edgelist_to_ell(g: EdgeList, k: Optional[int] = None) -> ELL:
    """ELLPACK of the *Laplacian* of g: diag = weighted degree, off-diag = -w."""
    a = edgelist_to_csr(g)
    ell = csr_to_ell(a, k=k)
    return ELL(cols=ell.cols, vals=-ell.vals, diag=ell.diag)


def laplacian_dense(g: EdgeList, reweight: Optional[np.ndarray] = None) -> np.ndarray:
    """Dense Laplacian (testing oracle only). reweight multiplies edge weights."""
    w = np.asarray(g.weight, dtype=np.float64)
    if reweight is not None:
        w = w * np.asarray(reweight, dtype=np.float64)
    L = np.zeros((g.n, g.n), dtype=np.float64)
    s = np.asarray(g.src)
    d = np.asarray(g.dst)
    np.add.at(L, (s, d), -w)
    np.add.at(L, (d, s), -w)
    np.add.at(L, (s, s), w)
    np.add.at(L, (d, d), w)
    return L


class STInstance(NamedTuple):
    """An s-t min-cut instance: non-terminal graph + terminal edges.

    The layout mirrors the paper's decomposition (§3.3): ``graph`` is the
    non-terminal graph G~ over nodes 0..n-1; ``s_weight[u]`` / ``t_weight[u]``
    are the terminal edge weights c({s,u}) / c({t,u}) (0 when absent).
    The full graph G has n+2 nodes with s = n, t = n+1.
    """

    graph: EdgeList
    s_weight: np.ndarray  # float[n]
    t_weight: np.ndarray  # float[n]

    @property
    def n(self) -> int:
        return self.graph.n

    @property
    def s(self) -> int:
        return self.graph.n

    @property
    def t(self) -> int:
        return self.graph.n + 1

    def full_edgelist(self) -> EdgeList:
        """Materialize the full graph including terminal edges (oracle paths)."""
        su = np.nonzero(np.asarray(self.s_weight) > 0)[0]
        tu = np.nonzero(np.asarray(self.t_weight) > 0)[0]
        src = np.concatenate([np.asarray(self.graph.src),
                              np.full(su.shape, self.s, dtype=np.int64),
                              np.full(tu.shape, self.t, dtype=np.int64)])
        dst = np.concatenate([np.asarray(self.graph.dst), su, tu])
        w = np.concatenate([np.asarray(self.graph.weight),
                            np.asarray(self.s_weight)[su],
                            np.asarray(self.t_weight)[tu]])
        return EdgeList(src=src.astype(np.int32), dst=dst.astype(np.int32),
                        weight=w, n=self.n + 2)

    def cut_value(self, in_source: np.ndarray) -> float:
        """cut(S, S̄) for a boolean indicator over non-terminal nodes
        (True = source side).  Includes terminal edges."""
        ind = np.asarray(in_source, dtype=bool)
        s_, d_ = np.asarray(self.graph.src), np.asarray(self.graph.dst)
        w = np.asarray(self.graph.weight, dtype=np.float64)
        crossing = ind[s_] != ind[d_]
        val = float(np.sum(w[crossing]))
        # terminal edges: s->u cut when u on sink side; t->u cut when u on source side
        val += float(np.sum(np.asarray(self.s_weight, dtype=np.float64)[~ind]))
        val += float(np.sum(np.asarray(self.t_weight, dtype=np.float64)[ind]))
        return val


def permute_instance(inst: STInstance, perm: np.ndarray) -> STInstance:
    """Relabel non-terminal nodes of an instance: new_id = perm[old_id]."""
    perm = np.asarray(perm)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(perm.shape[0])
    return STInstance(
        graph=inst.graph.permute_nodes(perm),
        s_weight=np.asarray(inst.s_weight)[inv],
        t_weight=np.asarray(inst.t_weight)[inv],
    )
