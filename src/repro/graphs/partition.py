"""METIS-lite: multilevel k-way graph partitioning on the host.

The paper (§3.2) partitions the non-terminal graph once with ParMETIS,
reorders nodes so each component is contiguous, and extracts the block-Jacobi
preconditioner as the block diagonal of P L̃ Pᵀ.  We reproduce the same
pipeline with a self-contained multilevel partitioner:

  1. *coarsen* by heavy-edge matching until the graph is small,
  2. *initial partition* by greedy BFS region growing (balanced volumes),
  3. *uncoarsen + refine* with boundary greedy moves (KL/FM-style gains).

Quality target is the paper's: balanced blocks and a small weighted edge cut
(objective (i)/(ii) in §3.2).  This is setup-time host work (numpy), exactly
as in the paper where partitioning is a separate phase (Table 2, col 1).
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from .structures import EdgeList, edgelist_to_csr


def bfs_grow(g: EdgeList, frac: float = 0.5, seed: int = 0) -> np.ndarray:
    """Grow a BFS region from a random seed until ``frac`` of total volume.
    Used for geometric-bisection-style seed sets (paper §5.1)."""
    rng = np.random.default_rng(seed)
    csr = edgelist_to_csr(g)
    d = g.weighted_degrees()
    target = float(d.sum()) * frac
    start = int(rng.integers(g.n))
    visited = np.zeros(g.n, dtype=bool)
    frontier = [start]
    visited[start] = True
    vol = d[start]
    out = [start]
    while frontier and vol < target:
        nxt = []
        for u in frontier:
            for v in csr.indices[csr.indptr[u]:csr.indptr[u + 1]]:
                v = int(v)
                if not visited[v]:
                    visited[v] = True
                    nxt.append(v)
                    out.append(v)
                    vol += d[v]
                    if vol >= target:
                        break
            if vol >= target:
                break
        frontier = nxt
    return np.asarray(out, dtype=np.int64)


def _heavy_edge_matching(g: EdgeList, rng: np.random.Generator) -> np.ndarray:
    """Greedy heavy-edge matching; returns coarse label per node."""
    order = np.argsort(-np.asarray(g.weight, dtype=np.float64), kind="stable")
    matched = np.full(g.n, -1, dtype=np.int64)
    src = np.asarray(g.src)[order]
    dst = np.asarray(g.dst)[order]
    nxt = 0
    for u, v in zip(src, dst):
        if matched[u] < 0 and matched[v] < 0:
            matched[u] = matched[v] = nxt
            nxt += 1
    for u in range(g.n):
        if matched[u] < 0:
            matched[u] = nxt
            nxt += 1
    return matched


def _contract(g: EdgeList, labels: np.ndarray, node_w: np.ndarray) -> Tuple[EdgeList, np.ndarray]:
    """Contract nodes by ``labels`` (coarse ids 0..nc-1), summing parallel
    edge weights and node weights; drops resulting self loops."""
    nc = int(labels.max()) + 1
    from .structures import canonicalize_edges
    lo, hi, wsum = canonicalize_edges(labels[np.asarray(g.src)],
                                      labels[np.asarray(g.dst)],
                                      g.weight, nc, merge="sum")
    cw = np.zeros(nc, dtype=np.float64)
    np.add.at(cw, labels, node_w)
    cg = EdgeList(src=lo.astype(np.int32), dst=hi.astype(np.int32),
                  weight=wsum, n=nc)
    return cg, cw


def _initial_kway(g: EdgeList, node_w: np.ndarray, p: int,
                  rng: np.random.Generator) -> np.ndarray:
    """Greedy balanced BFS region growing into p parts on the coarsest graph."""
    csr = edgelist_to_csr(g)
    total = float(node_w.sum())
    target = total / p
    labels = np.full(g.n, -1, dtype=np.int64)
    remaining = set(range(g.n))
    for part in range(p - 1):
        if not remaining:
            break
        start = int(rng.choice(list(remaining)))
        vol = 0.0
        frontier = [start]
        labels[start] = part
        remaining.discard(start)
        vol += node_w[start]
        while frontier and vol < target:
            nf = []
            for u in frontier:
                for v in csr.indices[csr.indptr[u]:csr.indptr[u + 1]]:
                    v = int(v)
                    if labels[v] < 0:
                        labels[v] = part
                        remaining.discard(v)
                        vol += node_w[v]
                        nf.append(v)
                        if vol >= target:
                            break
                if vol >= target:
                    break
            frontier = nf
    for u in remaining:
        labels[u] = p - 1
    return labels


def _refine(g: EdgeList, labels: np.ndarray, node_w: np.ndarray, p: int,
            n_pass: int = 4, imbalance: float = 1.1) -> np.ndarray:
    """Boundary greedy refinement: move a node to the neighbouring part with
    the largest positive gain if balance permits."""
    csr = edgelist_to_csr(g)
    labels = labels.copy()
    part_w = np.zeros(p)
    np.add.at(part_w, labels, node_w)
    limit = node_w.sum() / p * imbalance
    for _ in range(n_pass):
        moved = 0
        # boundary nodes: any neighbour in another part
        nbr_lab = labels[csr.indices]
        own = np.repeat(labels, np.diff(csr.indptr))
        is_boundary = np.zeros(g.n, dtype=bool)
        np.logical_or.at(is_boundary, np.repeat(np.arange(g.n), np.diff(csr.indptr)),
                         nbr_lab != own)
        for u in np.nonzero(is_boundary)[0]:
            lo, hi = csr.indptr[u], csr.indptr[u + 1]
            labs = labels[csr.indices[lo:hi]]
            wts = csr.data[lo:hi]
            cur = labels[u]
            # connectivity to each candidate part
            gains = {}
            internal = float(wts[labs == cur].sum())
            for lab in np.unique(labs):
                if lab == cur:
                    continue
                ext = float(wts[labs == lab].sum())
                gains[int(lab)] = ext - internal
            if not gains:
                continue
            best = max(gains, key=gains.get)
            if gains[best] > 1e-12 and part_w[best] + node_w[u] <= limit:
                part_w[cur] -= node_w[u]
                part_w[best] += node_w[u]
                labels[u] = best
                moved += 1
        if moved == 0:
            break
    return labels


def partition_kway(g: EdgeList, p: int, seed: int = 0,
                   coarsen_to: int = 4000) -> np.ndarray:
    """Multilevel k-way partition; returns int64 labels in [0, p)."""
    if p <= 1:
        return np.zeros(g.n, dtype=np.int64)
    rng = np.random.default_rng(seed)
    node_w = g.weighted_degrees() + 1e-9

    levels: List[Tuple[EdgeList, np.ndarray, np.ndarray]] = []  # (graph, node_w, labels->coarse)
    cur_g, cur_w = g, node_w
    while cur_g.n > max(coarsen_to, 8 * p) and cur_g.m > 0:
        match = _heavy_edge_matching(cur_g, rng)
        if int(match.max()) + 1 >= cur_g.n:  # no progress
            break
        levels.append((cur_g, cur_w, match))
        cur_g, cur_w = _contract(cur_g, match, cur_w)

    labels = _initial_kway(cur_g, cur_w, p, rng)
    labels = _refine(cur_g, labels, cur_w, p)

    while levels:
        fine_g, fine_w, match = levels.pop()
        labels = labels[match]
        labels = _refine(fine_g, labels, fine_w, p)
    return labels


def cut_weight(g: EdgeList, labels: np.ndarray) -> float:
    s = np.asarray(g.src)
    d = np.asarray(g.dst)
    w = np.asarray(g.weight, dtype=np.float64)
    return float(w[labels[s] != labels[d]].sum())


def partition_order(labels: np.ndarray, seed: int = 0) -> np.ndarray:
    """Permutation perm with new_id = perm[old_id], grouping nodes of the same
    part contiguously (the paper's reordering P in §3.2)."""
    order = np.argsort(labels, kind="stable")  # order[new] = old
    perm = np.empty_like(order)
    perm[order] = np.arange(order.shape[0])
    return perm


def block_ranges(labels: np.ndarray, p: int) -> List[Tuple[int, int]]:
    """Contiguous [start, end) ranges per part after ``partition_order``."""
    counts = np.bincount(labels, minlength=p)
    ends = np.cumsum(counts)
    starts = ends - counts
    return list(zip(starts.tolist(), ends.tolist()))
