"""Synthetic graph/instance generators mirroring the paper's two data families.

The paper evaluates on (a) road networks (planar, avg degree ~2.5, from the UF
sparse-matrix collection) and (b) N-D grid segmentation graphs (6/26-connected
voxel grids from the UWO max-flow datasets, weights made float by adding
U[0,1] noise).  Offline we synthesize statistically matching families:

* ``road_like``      — jittered-grid planar nets with degree ~2.6 (road proxy)
* ``grid_2d/grid_3d``— 4/6/26-connected grids with smooth+noisy capacities
* ``random_regular`` — small test graphs
* ``flow_improve_instance`` — terminal edges built exactly like FlowImprove [1]
  from a seed bisection (this is how the paper makes road networks into s-t
  min-cut instances, §5.1)
* ``segmentation_instance`` — unary potentials from a smooth random field
  (grid graphs, §5.1's MRI-style instances)
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from .structures import EdgeList, STInstance, canonicalize_edges


def _dedup_and_connect(src, dst, w, n, rng) -> EdgeList:
    """Canonicalize (u<v), drop dups/self-loops, then add spanning edges to
    make the graph connected."""
    lo, hi, w = canonicalize_edges(src, dst, w, n, merge="first")

    # union-find to connect components
    parent = np.arange(n, dtype=np.int64)

    def find(x):
        root = x
        while parent[root] != root:
            root = parent[root]
        while parent[x] != root:
            parent[x], x = root, parent[x]
        return root

    for a, b in zip(lo, hi):
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[ra] = rb
    roots = np.array(sorted({find(i) for i in range(n)}))
    extra_src, extra_dst = [], []
    for i in range(len(roots) - 1):
        extra_src.append(roots[i])
        extra_dst.append(roots[i + 1])
        parent[find(roots[i])] = find(roots[i + 1])
    if extra_src:
        lo = np.concatenate([lo, np.minimum(extra_src, extra_dst)])
        hi = np.concatenate([hi, np.maximum(extra_src, extra_dst)])
        w = np.concatenate([w, rng.uniform(0.5, 1.5, size=len(extra_src))])
    return EdgeList(src=lo.astype(np.int32), dst=hi.astype(np.int32), weight=w, n=n).validate()


def road_like(side: int, seed: int = 0, keep_prob: float = 0.62) -> EdgeList:
    """Planar road-network proxy: jittered grid, 4-neighbour links kept with
    probability ``keep_prob`` (gives avg degree ≈ 2.5, like usroads-48)."""
    rng = np.random.default_rng(seed)
    n = side * side
    ii, jj = np.meshgrid(np.arange(side), np.arange(side), indexing="ij")
    idx = (ii * side + jj).ravel()
    right = np.stack([idx[(jj < side - 1).ravel()],
                      (idx + 1)[(jj < side - 1).ravel()]], axis=1)
    down = np.stack([idx[(ii < side - 1).ravel()],
                     (idx + side)[(ii < side - 1).ravel()]], axis=1)
    edges = np.concatenate([right, down], axis=0)
    keep = rng.uniform(size=edges.shape[0]) < keep_prob
    edges = edges[keep]
    # road segment "lengths" -> float weights
    w = rng.uniform(0.2, 2.0, size=edges.shape[0])
    return _dedup_and_connect(edges[:, 0], edges[:, 1], w, n, rng)


def grid_2d(h: int, w: int, seed: int = 0, smooth: bool = True) -> EdgeList:
    """4-connected 2D grid with smooth random capacities + U[0,1] noise."""
    rng = np.random.default_rng(seed)
    n = h * w
    ii, jj = np.meshgrid(np.arange(h), np.arange(w), indexing="ij")
    idx = (ii * w + jj).ravel()
    src = np.concatenate([idx[(jj < w - 1).ravel()], idx[(ii < h - 1).ravel()]])
    dst = np.concatenate([(idx + 1)[(jj < w - 1).ravel()], (idx + w)[(ii < h - 1).ravel()]])
    base = _smooth_field((h, w), rng) if smooth else np.ones((h, w))
    f = base.ravel()
    wts = 2.0 + 2.0 * np.exp(-np.abs(f[src] - f[dst]) * 4.0) + rng.uniform(0, 1, size=src.shape[0])
    return _dedup_and_connect(src, dst, wts, n, rng)


def grid_3d(d: int, h: int, w: int, conn: int = 6, seed: int = 0) -> EdgeList:
    """6- or 26-connected 3D voxel grid (MRI-scan proxy)."""
    assert conn in (6, 26)
    rng = np.random.default_rng(seed)
    n = d * h * w
    coords = np.stack(np.meshgrid(np.arange(d), np.arange(h), np.arange(w),
                                  indexing="ij"), axis=-1).reshape(-1, 3)
    idx = coords[:, 0] * h * w + coords[:, 1] * w + coords[:, 2]
    offs = []
    full = [(dz, dy, dx) for dz in (-1, 0, 1) for dy in (-1, 0, 1) for dx in (-1, 0, 1)]
    for o in full:
        if o == (0, 0, 0):
            continue
        if conn == 6 and sum(abs(v) for v in o) != 1:
            continue
        # keep each undirected pair once: lexicographically positive offset
        if o > (0, 0, 0):
            offs.append(o)
    srcs, dsts = [], []
    for dz, dy, dx in offs:
        nc = coords + np.array([dz, dy, dx])
        ok = ((nc[:, 0] >= 0) & (nc[:, 0] < d) & (nc[:, 1] >= 0) & (nc[:, 1] < h)
              & (nc[:, 2] >= 0) & (nc[:, 2] < w))
        srcs.append(idx[ok])
        dsts.append(nc[ok, 0] * h * w + nc[ok, 1] * w + nc[ok, 2])
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    field = _smooth_field((d, h, w), rng).ravel()
    wts = 1.0 + 4.0 * np.exp(-np.abs(field[src] - field[dst]) * 3.0) + rng.uniform(0, 1, size=src.shape[0])
    return _dedup_and_connect(src, dst, wts, n, rng)


def social_like(n: int, seed: int = 0, m_max: int = 2) -> EdgeList:
    """Preferential-attachment social-graph proxy (power-law degrees).

    Each new node attaches to 1..``m_max`` existing nodes sampled
    proportionally to degree: a dense hub core fringed with degree-1
    leaves and degree-2 chains — the structure the kernelization rules
    (``repro.presolve``) eliminate.  Heavy-tailed edge weights."""
    if n < 2:
        raise ValueError(f"social_like needs n >= 2, got {n}")
    rng = np.random.default_rng(seed)
    src_l, dst_l = [0], [1]
    pool = [0, 1]                   # one entry per edge endpoint
    for v in range(2, n):
        k = int(rng.integers(1, m_max + 1))
        targets = {int(pool[i]) for i in rng.integers(0, len(pool), size=k)}
        for t in targets:
            src_l.append(t)
            dst_l.append(v)
            pool.extend((t, v))
    w = rng.lognormal(0.0, 0.75, size=len(src_l))
    return _dedup_and_connect(np.asarray(src_l), np.asarray(dst_l), w, n, rng)


def random_regular(n: int, deg: int, seed: int = 0) -> EdgeList:
    """Small random near-regular test graph (configuration-model style)."""
    rng = np.random.default_rng(seed)
    stubs = np.repeat(np.arange(n), deg)
    rng.shuffle(stubs)
    half = len(stubs) // 2
    src, dst = stubs[:half], stubs[half:2 * half]
    w = rng.uniform(0.5, 2.0, size=half)
    return _dedup_and_connect(src, dst, w, n, rng)


def _smooth_field(shape, rng) -> np.ndarray:
    """Cheap smooth random field: random gaussians + box blur."""
    f = rng.standard_normal(shape)
    for axis in range(len(shape)):
        for _ in range(3):
            f = (f + np.roll(f, 1, axis=axis) + np.roll(f, -1, axis=axis)) / 3.0
    return f


def flow_improve_instance(g: EdgeList, seed_set: Optional[np.ndarray] = None,
                          alpha: Optional[float] = None, seed: int = 0) -> STInstance:
    """Build an s-t instance from a seed bisection exactly as FlowImprove [1]
    does (the paper's §5.1 road-network recipe): s connects to every u in the
    seed set A with weight d_w(u); t connects to every u ∉ A with weight
    α·d_w(u), α = vol(A)/vol(Ā).  Weights are floating point by construction.
    """
    rng = np.random.default_rng(seed)
    d = g.weighted_degrees()
    if seed_set is None:
        # geometric-ish bisection: BFS from a random node until half the volume
        from .partition import bfs_grow
        seed_set = bfs_grow(g, frac=0.5, seed=int(rng.integers(1 << 31)))
    ind = np.zeros(g.n, dtype=bool)
    ind[np.asarray(seed_set)] = True
    volA = float(d[ind].sum())
    volB = float(d[~ind].sum())
    if alpha is None:
        alpha = volA / max(volB, 1e-12)
    s_w = np.where(ind, d, 0.0)
    t_w = np.where(~ind, alpha * d, 0.0)
    return STInstance(graph=g, s_weight=s_w, t_weight=t_w)


def segmentation_instance(g: EdgeList, shape: Tuple[int, ...], seed: int = 0,
                          unary_strength: Optional[float] = None) -> STInstance:
    """Unary potentials from a smooth field (image/MRI segmentation proxy):
    source affinity where field > threshold, sink affinity elsewhere.

    ``unary_strength`` scales the terminal weights; the default ties it to
    the mean weighted degree so the min cut trades off boundary length
    against unary disagreement (nontrivial cuts even on 26-conn grids)."""
    rng = np.random.default_rng(seed)
    field = _smooth_field(shape, rng).ravel()
    assert field.shape[0] == g.n
    if unary_strength is None:
        unary_strength = 0.55 * float(g.weighted_degrees().mean())
    lo, hi = np.quantile(field, [0.35, 0.65])
    u = unary_strength
    s_w = np.where(field > hi, u * (1.0 + field - hi), 0.0) \
        + rng.uniform(0, 0.05 * u, g.n)
    t_w = np.where(field < lo, u * (1.0 + lo - field), 0.0) \
        + rng.uniform(0, 0.05 * u, g.n)
    return STInstance(graph=g, s_weight=s_w, t_weight=t_w)
