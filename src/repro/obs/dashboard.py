"""JSONL span-sink reader: aggregation + text dashboard rendering.

Pure functions over the trace schema ``trace.py`` writes (one JSON span
per line).  The ``repro.launch.obs`` CLI is a thin argparse shell around
:func:`load_spans` → :func:`aggregate` → :func:`render`, optionally in a
follow loop (tail the file, re-render).

The "flamegraph-style" summary groups spans by their PATH — the chain of
ancestor names joined with ``>`` (``serve.batch>session.solve_batch>
session.irls``) — so the tree view shows, per call site, call count,
total wall time, and SELF time (total minus child time), sorted so the
expensive paths surface first.  Parent links are resolved per thread via
``span_id``/``parent_id``; orphans (parent outside the ring/file window)
root their own subtree, which keeps partial tails readable.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Optional, Tuple

__all__ = ["load_spans", "aggregate", "render", "span_names", "percentile"]


def load_spans(path: str, offset: int = 0) -> Tuple[List[Dict[str, Any]], int]:
    """Read spans from a JSONL sink starting at byte ``offset``.

    Returns ``(spans, new_offset)``; skips partial/corrupt trailing lines
    (a live writer may be mid-line), so follow mode can call this
    repeatedly with the returned offset.
    """
    spans: List[Dict[str, Any]] = []
    with open(path, "r") as fh:
        fh.seek(offset)
        while True:
            pos = fh.tell()
            line = fh.readline()
            if not line:
                break
            if not line.endswith("\n"):
                return spans, pos           # partial tail: retry next round
            line = line.strip()
            if not line:
                continue
            try:
                spans.append(json.loads(line))
            except json.JSONDecodeError:
                continue
        return spans, fh.tell()


def span_names(spans: Iterable[Dict[str, Any]]) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for s in spans:
        out[s["name"]] = out.get(s["name"], 0) + 1
    return out


def percentile(samples: List[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) of a duration sample list."""
    if not samples:
        return 0.0
    xs = sorted(samples)
    i = min(len(xs) - 1, max(0, int(round(q / 100.0 * (len(xs) - 1)))))
    return xs[i]


def aggregate(spans: List[Dict[str, Any]]) -> Dict[str, Dict[str, float]]:
    """Per-PATH aggregates: count, total seconds, self seconds, errors,
    and the raw duration samples (``durations``) the renderer turns into
    p50/p99 percentiles — tail latency per call site, not just the mean.

    ``self`` subtracts each span's DIRECT children's durations from its
    own, so a path's self time is where the wall clock actually went.
    """
    by_id = {s["span_id"]: s for s in spans if "span_id" in s}

    def path_of(s) -> str:
        parts = [s["name"]]
        seen = {s.get("span_id")}
        p = s.get("parent_id")
        while p is not None and p in by_id and p not in seen:
            seen.add(p)
            parent = by_id[p]
            parts.append(parent["name"])
            p = parent.get("parent_id")
        return ">".join(reversed(parts))

    child_time: Dict[int, float] = {}
    for s in spans:
        p = s.get("parent_id")
        if p is not None and p in by_id:
            child_time[p] = child_time.get(p, 0.0) + float(s.get("dur_s", 0.0))

    agg: Dict[str, Dict[str, float]] = {}
    for s in spans:
        path = path_of(s)
        d = agg.setdefault(path, {"count": 0, "total_s": 0.0, "self_s": 0.0,
                                  "errors": 0, "durations": []})
        dur = float(s.get("dur_s", 0.0))
        d["count"] += 1
        d["total_s"] += dur
        d["self_s"] += max(0.0, dur - child_time.get(s.get("span_id"), 0.0))
        d["durations"].append(dur)
        if s.get("error"):
            d["errors"] += 1
    return agg


def render(agg: Dict[str, Dict[str, float]], top: int = 30,
           title: str = "span summary", sort: Optional[str] = None) -> str:
    """Flamegraph-style text tree, expensive paths first.

    ``sort=None`` keeps the tree layout (roots by total time, children
    indented beneath them).  ``sort="self"|"p99"|"count"`` flattens the
    listing and ranks every path by that column descending — the hunting
    view ("which call site burns the most self time / has the worst
    tail") rather than the structural one.
    """
    if not agg:
        return f"{title}: (no spans)"
    if sort is not None:
        keys = {"self": lambda d: d["self_s"],
                "p99": lambda d: percentile(d.get("durations", []), 99),
                "count": lambda d: d["count"]}
        if sort not in keys:
            raise ValueError(f"sort must be one of {sorted(keys)}: {sort!r}")
        order = sorted(agg, key=lambda p: (-keys[sort](agg[p]), p))
    else:
        # order: by root path total desc, then depth-first lexicographic
        roots: Dict[str, float] = {}
        for path, d in agg.items():
            root = path.split(">", 1)[0]
            roots[root] = roots.get(root, 0.0) + (d["total_s"]
                                                  if ">" not in path else 0.0)
        order = sorted(agg, key=lambda p: (-roots.get(p.split(">", 1)[0],
                                                      0.0), p))
    lines = [title,
             f"  {'path':<44} {'count':>7} {'total':>10} {'self':>10} "
             f"{'mean':>9} {'p50':>9} {'p99':>9}"]
    for path in order[:top]:
        d = agg[path]
        if sort is None:
            depth = path.count(">")
            name = ("  " * depth) + path.rsplit(">", 1)[-1]
        else:
            name = path
        if len(name) > 44:
            name = name[:41] + "..."
        mean = d["total_s"] / d["count"] if d["count"] else 0.0
        durs = d.get("durations", [])
        p50, p99 = percentile(durs, 50), percentile(durs, 99)
        err = f"  !{int(d['errors'])}err" if d["errors"] else ""
        lines.append(f"  {name:<44} {int(d['count']):>7} "
                     f"{d['total_s'] * 1e3:>8.1f}ms {d['self_s'] * 1e3:>8.1f}ms "
                     f"{mean * 1e3:>7.2f}ms {p50 * 1e3:>7.2f}ms "
                     f"{p99 * 1e3:>7.2f}ms{err}")
    if len(order) > top:
        lines.append(f"  ... {len(order) - top} more paths")
    return "\n".join(lines)
