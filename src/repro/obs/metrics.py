"""Bounded metrics primitives + registry with Prometheus/JSON exposition.

Three instrument kinds, all thread-safe and all O(1) memory:

* :class:`Counter` — monotonically increasing exact total.
* :class:`Gauge`   — last-set value.
* :class:`Histogram` — EXACT count/sum/min/max plus a bounded
  :class:`Reservoir` of samples (Vitter's Algorithm R: each of the n
  observations ends up in the k-slot sample with probability k/n) for
  percentile estimates.  This is what replaced the serving layer's
  unbounded ``_samples`` lists: sustained traffic keeps percentiles
  honest at flat memory.

:class:`MetricsRegistry` names the instruments and renders them two
ways: ``snapshot()`` (plain JSON dict — what ``BENCH_*.json`` payloads
and ``stats()`` embed) and ``prometheus_text()`` (text exposition
format: counters as ``_total``, histograms as summaries with quantile
labels, ``# TYPE``/``# HELP`` comments).  ``parse_prometheus_text`` is
the minimal inverse used by the round-trip test and the dashboard.

A module-level default registry (``get_registry()``) collects the
always-on cross-subsystem counters (solves, batches, kernelizations,
cut-tree waves, divergence sentinels) — increments are one lock + one
add, cheap enough to leave unconditional.
"""
from __future__ import annotations

import math
import random
import threading
from typing import Dict, List, Optional, Tuple

__all__ = ["Reservoir", "Counter", "Gauge", "Histogram", "MetricsRegistry",
           "get_registry", "parse_prometheus_text"]


def _percentile(samples: List[float], p: float) -> float:
    if not samples:
        return float("nan")
    s = sorted(samples)
    if len(s) == 1:
        return float(s[0])
    rank = (p / 100.0) * (len(s) - 1)
    lo = int(math.floor(rank))
    hi = min(lo + 1, len(s) - 1)
    frac = rank - lo
    return float(s[lo] * (1.0 - frac) + s[hi] * frac)


class Reservoir:
    """Bounded uniform sample of an unbounded stream (Algorithm R).

    Exact aggregates (``count``/``total``/``min``/``max``) are tracked on
    the side, so only the percentile estimate is sampled.  Deterministic
    given ``seed`` — tests and benchmarks reproduce.
    """

    def __init__(self, maxlen: int = 2048, seed: int = 0):
        if maxlen < 1:
            raise ValueError(f"maxlen must be >= 1, got {maxlen}")
        self.maxlen = int(maxlen)
        self._rng = random.Random(seed)
        self._samples: List[float] = []
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def add(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if len(self._samples) < self.maxlen:
            self._samples.append(v)
        else:
            j = self._rng.randrange(self.count)
            if j < self.maxlen:
                self._samples[j] = v

    def values(self) -> List[float]:
        return list(self._samples)

    def __len__(self) -> int:
        return len(self._samples)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else float("nan")

    def percentile(self, p: float) -> float:
        return _percentile(self._samples, p)


class Counter:
    """Monotone exact counter."""

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError(f"counter {self.name} cannot decrease (v={v})")
        with self._lock:
            self._value += v

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge:
    """Last-set value."""

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value = float("nan")
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram:
    """Exact aggregates + bounded-reservoir percentiles."""

    QUANTILES = (50, 90, 99)

    def __init__(self, name: str, help: str = "", max_samples: int = 2048,
                 seed: int = 0):
        self.name = name
        self.help = help
        self._res = Reservoir(maxlen=max_samples, seed=seed)
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        with self._lock:
            self._res.add(v)

    @property
    def count(self) -> int:
        with self._lock:
            return self._res.count

    @property
    def total(self) -> float:
        with self._lock:
            return self._res.total

    def percentile(self, p: float) -> float:
        with self._lock:
            return self._res.percentile(p)

    def values(self) -> List[float]:
        """The bounded reservoir sample (NOT every observation)."""
        with self._lock:
            return self._res.values()

    @property
    def max(self) -> float:
        with self._lock:
            return self._res.max if self._res.count else float("nan")

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            r = self._res
            out = {"count": r.count, "sum": r.total,
                   "min": r.min if r.count else float("nan"),
                   "max": r.max if r.count else float("nan"),
                   "mean": r.mean}
            for q in self.QUANTILES:
                out[f"p{q}"] = r.percentile(q)
        return out


def _sanitize(name: str) -> str:
    out = "".join(ch if (ch.isalnum() or ch == "_") else "_" for ch in name)
    return out if not out[:1].isdigit() else "_" + out


class MetricsRegistry:
    """Named instrument store with JSON + Prometheus exposition.

    ``counter``/``gauge``/``histogram`` are get-or-create (idempotent, so
    call sites don't coordinate); a name can only ever hold one kind.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: "Dict[str, object]" = {}

    def _get(self, name: str, cls, **kwargs):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, **kwargs)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} is a "
                                f"{type(m).__name__}, not a {cls.__name__}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, Counter, help=help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, Gauge, help=help)

    def histogram(self, name: str, help: str = "",
                  max_samples: int = 2048) -> Histogram:
        return self._get(name, Histogram, help=help,
                         max_samples=max_samples)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()

    def snapshot(self) -> Dict[str, object]:
        """Everything, as one JSON-serializable dict."""
        with self._lock:
            items = sorted(self._metrics.items())
        out: Dict[str, object] = {}
        for name, m in items:
            if isinstance(m, Histogram):
                out[name] = m.snapshot()
            else:
                out[name] = m.value
        return out

    def prometheus_text(self, prefix: str = "") -> str:
        """Prometheus text exposition format (0.0.4)."""
        with self._lock:
            items = sorted(self._metrics.items())
        lines: List[str] = []
        for name, m in items:
            pname = _sanitize(prefix + name)
            if isinstance(m, Counter):
                if not pname.endswith("_total"):
                    pname += "_total"
                if m.help:
                    lines.append(f"# HELP {pname} {m.help}")
                lines.append(f"# TYPE {pname} counter")
                lines.append(f"{pname} {m.value:.17g}")
            elif isinstance(m, Gauge):
                if m.help:
                    lines.append(f"# HELP {pname} {m.help}")
                lines.append(f"# TYPE {pname} gauge")
                v = m.value
                lines.append(f"{pname} {'NaN' if math.isnan(v) else format(v, '.17g')}")
            elif isinstance(m, Histogram):
                if m.help:
                    lines.append(f"# HELP {pname} {m.help}")
                lines.append(f"# TYPE {pname} summary")
                s = m.snapshot()
                for q in Histogram.QUANTILES:
                    v = s[f"p{q}"]
                    lines.append(
                        f'{pname}{{quantile="{q / 100.0:g}"}} '
                        f"{'NaN' if math.isnan(v) else format(v, '.17g')}")
                lines.append(f"{pname}_sum {s['sum']:.17g}")
                lines.append(f"{pname}_count {s['count']}")
        return "\n".join(lines) + ("\n" if lines else "")


def parse_prometheus_text(text: str) -> Dict[str, object]:
    """Minimal inverse of ``prometheus_text`` (the round-trip checker).

    Returns ``{metric_name: value}`` for counters/gauges and
    ``{metric_name: {"quantiles": {q: v}, "sum": s, "count": c}}`` for
    summaries.  Ignores HELP lines; TYPE lines decide the shape.
    """
    types: Dict[str, str] = {}
    out: Dict[str, object] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        if line.startswith("# TYPE "):
            _, _, name, kind = line.split(None, 3)
            types[name] = kind
            continue
        if line.startswith("#"):
            continue
        key, _, val = line.rpartition(" ")
        key = key.strip()
        v = float(val)
        if "{" in key:
            base, _, label = key.partition("{")
            label = label.rstrip("}")
            q = float(label.split("=")[1].strip('"'))
            d = out.setdefault(base, {"quantiles": {}, "sum": None,
                                      "count": None})
            d["quantiles"][q] = v
        elif key.endswith("_sum") and types.get(key[:-4]) == "summary":
            out.setdefault(key[:-4], {"quantiles": {}, "sum": None,
                                      "count": None})["sum"] = v
        elif key.endswith("_count") and types.get(key[:-6]) == "summary":
            out.setdefault(key[:-6], {"quantiles": {}, "sum": None,
                                      "count": None})["count"] = v
        else:
            out[key] = v
    return out


_REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-wide default registry (always-on subsystem counters)."""
    return _REGISTRY
