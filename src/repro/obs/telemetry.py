"""Solver telemetry: the per-solve "why" record and its aggregation.

``SolveResult.telemetry`` (a plain dict, JSON-ready — built by
:func:`build_solve_telemetry` inside ``MinCutSession``) captures what the
timings alone cannot explain:

    backend            host | scanned | sharded
    n, m               instance size actually solved (kernel size under
                       presolve)
    irls_configured    T of the schedule
    irls_executed      iterations that did work (adaptive early exit
                       freezes the tail at 0 PCG iterations)
    pcg_per_iter       PCG spend per IRLS iteration (list)
    pcg_total          sum of the above
    rel_history        per-iteration final PCG relative residual
    eps_first/eps_last eps schedule endpoints (+ schedule name)
    adaptive           early-exit schedule active?
    early_exit_iter    first frozen iteration (None = ran the full T)
    warm_start         True/False/None (None = not applicable)
    presolve           kernelization stats (kernel_n/m, reductions,
                       per-rule fired counts, base) or None
    phases             per-phase wall seconds (setup/presolve/irls/
                       rounding/total; the engine adds queue/assembly)
    flops, hbm_bytes   device-side static cost estimate of the compiled
                       program(s) this solve executed (repro.obs.perf.
                       profile: cost_analysis × while-trip correction);
                       None when profiling is off
    achieved_gflops    flops / irls wall seconds / 1e9 (+ achieved_gbps,
                       roofline_fraction vs the TPU-v5e roofline model)
    clamped_reweights  sharded reweight-clamp hits this solve (the
                       cfg.reweight_clamp float32 mitigation); None when
                       not applicable
    worker             dispatch-worker id (engine-served solves only —
                       the continuous-batching pool attributes each
                       completed request to the worker that executed it)

:class:`TelemetryAggregator` folds those dicts into a bounded summary —
per ``MinCutSession`` (every session owns one) and per ``MinCutServer``
(the engine feeds completed requests in, queue time included), surfaced
by ``stats()["telemetry"]`` and attached to ``BENCH_*.json`` payloads so
the perf trajectory records why a number moved.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional

import numpy as np

from .metrics import Reservoir

__all__ = ["build_solve_telemetry", "TelemetryAggregator"]


def _as_float_list(x) -> Optional[List[float]]:
    if x is None:
        return None
    return [float(v) for v in np.asarray(x).ravel()]


def _as_int_list(x) -> Optional[List[int]]:
    if x is None:
        return None
    return [int(v) for v in np.asarray(x).ravel()]


def build_solve_telemetry(cfg, backend: str, n: int, m: int,
                          timings: Dict[str, float],
                          pcg_iters=None, residuals=None, diagnostics=None,
                          warm_start: Optional[bool] = None,
                          presolve: Optional[Dict[str, Any]] = None,
                          cost: Optional[Dict[str, Any]] = None,
                          clamped_reweights: Optional[int] = None,
                          ) -> Dict[str, Any]:
    """Assemble the per-solve telemetry dict (see module docstring).

    ``pcg_iters``/``residuals`` come from the scanned/sharded programs;
    the host backend supplies ``diagnostics`` (IRLSDiagnostics) instead.
    """
    from repro.core.irls import eps_schedule_array

    if diagnostics is not None and pcg_iters is None:
        pcg_iters = diagnostics.pcg_iters
    if diagnostics is not None and residuals is None:
        residuals = diagnostics.pcg_residuals
    iters = _as_int_list(pcg_iters)
    rels = _as_float_list(residuals)
    eps = eps_schedule_array(cfg)
    adaptive = bool(cfg.irls_tol > 0 or cfg.adaptive_tol)
    executed = None
    early_exit = None
    if iters is not None:
        nz = [i for i, it in enumerate(iters) if it > 0]
        executed = len(nz)
        # trailing zeros under the adaptive schedule = the frozen tail;
        # +1 maps the iteration index to 1-based "exited after iteration k"
        if adaptive and iters and iters[-1] == 0:
            early_exit = (nz[-1] + 1) if nz else 0
    cost = cost or {}
    return {
        "backend": backend,
        "n": int(n),
        "m": int(m),
        "flops": cost.get("flops"),
        "hbm_bytes": cost.get("hbm_bytes"),
        "achieved_gflops": cost.get("achieved_gflops"),
        "achieved_gbps": cost.get("achieved_gbps"),
        "roofline_fraction": cost.get("roofline_fraction"),
        "clamped_reweights": (int(clamped_reweights)
                              if clamped_reweights is not None else None),
        "irls_configured": int(cfg.n_irls),
        "irls_executed": executed,
        "pcg_per_iter": iters,
        "pcg_total": int(sum(iters)) if iters is not None else None,
        "rel_history": rels,
        "eps_first": float(eps[0]) if len(eps) else float(cfg.eps),
        "eps_last": float(eps[-1]) if len(eps) else float(cfg.eps),
        "eps_schedule": cfg.eps_schedule,
        "adaptive": adaptive,
        "early_exit_iter": early_exit,
        "warm_start": warm_start,
        "presolve": presolve,
        "phases": {k: float(v) for k, v in (timings or {}).items()},
    }


class TelemetryAggregator:
    """Bounded fold of per-solve telemetry dicts (thread-safe).

    ``add`` is cheap (lock + a handful of scalar updates + reservoir
    inserts); ``snapshot`` renders the aggregate the server/bench payloads
    embed: solve counts per backend, PCG spend distribution, phase time
    totals and shares, early-exit/warm-start/presolve rates, kernel
    reduction distribution.
    """

    def __init__(self, max_samples: int = 2048):
        self._lock = threading.Lock()
        self._max_samples = max_samples
        self._reset()

    def _reset(self) -> None:
        self.solves = 0
        self.by_backend: Dict[str, int] = {}
        self.by_worker: Dict[str, int] = {}
        self.pcg = Reservoir(self._max_samples)
        self.irls = Reservoir(self._max_samples)
        self.phase_totals: Dict[str, float] = {}
        self.adaptive_solves = 0
        self.early_exits = 0
        self.warm_hits = 0
        self.warm_known = 0
        self.presolve_solves = 0
        self.kernel_node_reduction = Reservoir(self._max_samples)
        self.flops_total = 0
        self.profiled_solves = 0
        self.achieved_gflops = Reservoir(self._max_samples)
        self.clamped_reweights_total = 0

    def clear(self) -> None:
        with self._lock:
            self._reset()

    def add(self, t: Optional[Dict[str, Any]]) -> None:
        if not t:
            return
        with self._lock:
            self.solves += 1
            b = t.get("backend", "?")
            self.by_backend[b] = self.by_backend.get(b, 0) + 1
            if t.get("worker") is not None:
                w = str(t["worker"])
                self.by_worker[w] = self.by_worker.get(w, 0) + 1
            if t.get("pcg_total") is not None:
                self.pcg.add(t["pcg_total"])
            if t.get("irls_executed") is not None:
                self.irls.add(t["irls_executed"])
            for ph, v in (t.get("phases") or {}).items():
                self.phase_totals[ph] = self.phase_totals.get(ph, 0.0) + v
            if t.get("adaptive"):
                self.adaptive_solves += 1
                if t.get("early_exit_iter") is not None:
                    self.early_exits += 1
            if t.get("warm_start") is not None:
                self.warm_known += 1
                if t["warm_start"]:
                    self.warm_hits += 1
            if t.get("flops"):
                self.flops_total += int(t["flops"])
                self.profiled_solves += 1
                if t.get("achieved_gflops") is not None:
                    self.achieved_gflops.add(t["achieved_gflops"])
            if t.get("clamped_reweights"):
                self.clamped_reweights_total += int(t["clamped_reweights"])
            p = t.get("presolve")
            if p:
                self.presolve_solves += 1
                if p.get("node_reduction") is not None and \
                        np.isfinite(p["node_reduction"]):
                    self.kernel_node_reduction.add(p["node_reduction"])

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            total = self.phase_totals.get("total", 0.0)
            phases = dict(self.phase_totals)
            shares = {ph: (v / total if total > 0 else float("nan"))
                      for ph, v in phases.items() if ph != "total"}
            return {
                "solves": self.solves,
                "by_backend": dict(self.by_backend),
                "by_worker": dict(self.by_worker),
                "mean_pcg_iters_per_solve": self.pcg.mean,
                "p90_pcg_iters_per_solve": self.pcg.percentile(90),
                "mean_irls_iters_per_solve": self.irls.mean,
                "phase_seconds": phases,
                "phase_share_of_total": shares,
                "adaptive_solves": self.adaptive_solves,
                "early_exit_rate": (self.early_exits / self.adaptive_solves
                                    if self.adaptive_solves else float("nan")),
                "warm_start_rate": (self.warm_hits / self.warm_known
                                    if self.warm_known else float("nan")),
                "presolve_solves": self.presolve_solves,
                "mean_kernel_node_reduction": self.kernel_node_reduction.mean,
                "profiled_solves": self.profiled_solves,
                "total_flops": self.flops_total,
                "mean_achieved_gflops": self.achieved_gflops.mean,
                "p90_achieved_gflops": self.achieved_gflops.percentile(90),
                "clamped_reweights_total": self.clamped_reweights_total,
            }
