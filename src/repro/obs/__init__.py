"""End-to-end observability: tracing, metrics, solver telemetry.

Zero-dependency (numpy only; jax imported lazily for fences) substrate
shared by every subsystem:

    trace      — thread-safe nested span tracer: in-memory ring +
                 optional JSONL sink + ``jax.profiler`` passthrough;
                 free when disabled (``trace.configure(enabled=True)``)
    metrics    — Counter/Gauge/Histogram (bounded reservoir) registry
                 with JSON + Prometheus-text exposition
    telemetry  — the ``SolveResult.telemetry`` schema and its
                 per-session / per-server aggregation
    dashboard  — JSONL sink reader + flamegraph-style text rendering
                 (driven by ``python -m repro.launch.obs``)

Instrumented span names by subsystem (the CI obs smoke asserts one of
each appears in a traced serve replay; docs/API.md "Observability" has
the full schema):

    serve.*     engine queue/assembly/dispatch/flush  (serve/engine.py)
    session.*   solve / solve_batch / presolve / irls / rounding phases
    presolve.*  kernelization fixpoint                (presolve/contract.py)
    cuttree.*   build / wave / speculation            (cuttree/gusfield.py)
    sharded.*   SPMD solve + collective gauges        (distributed/solver.py)
"""
from . import dashboard, metrics, telemetry, trace
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry, Reservoir,
                      get_registry, parse_prometheus_text)
from .telemetry import TelemetryAggregator, build_solve_telemetry
from .trace import Tracer, configure, enabled, event, fence, get_tracer, span


def bench_snapshot() -> dict:
    """Observability snapshot for ``BENCH_*.json`` payloads.

    Always includes the global metrics registry; includes a span-path
    summary only when tracing ran (the payload stays small and
    deterministic-ish otherwise).
    """
    out = {"metrics": get_registry().snapshot()}
    spans = trace.spans()
    if spans:
        agg = dashboard.aggregate([s.to_dict() for s in spans])
        out["span_paths"] = {
            path: {"count": int(d["count"]),
                   "total_s": d["total_s"], "self_s": d["self_s"]}
            for path, d in sorted(agg.items())}
    return out
