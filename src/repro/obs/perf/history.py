"""Append-only bench trajectory store: ``BENCH_HISTORY.jsonl``.

The ``BENCH_<name>.json`` snapshots are overwrite-in-place — they show
the LATEST number, not the trajectory, and give the comparator nothing
to estimate noise from.  This module is the missing history:
``benchmarks.run.write_payloads`` calls :func:`append_history` after
every bench run, appending one JSON line per extracted metric:

    {"bench": "irls", "variant": "smoke", "run": 3,
     "git_sha": "7d954e2", "metric": "topologies[grid]....s_per_solve",
     "value": 0.0042, "kind": "time", "direction": "lower"}

``variant`` separates smoke payloads (tiny CI instances) from full runs
— their values differ by orders of magnitude and must never share a
baseline.  ``run`` is a monotone per-(bench, variant) counter so "last
K entries" is well defined even when several benches interleave.  The
file is committed: the repo carries its own noise baseline, and CI
uploads the grown file as the trajectory artifact.
"""
from __future__ import annotations

import json
import os
import subprocess
from typing import Dict, List, Optional

from .schema import extract_metrics

__all__ = ["HISTORY_FILE", "history_path", "git_sha", "history_records",
           "append_history", "read_history"]

HISTORY_FILE = "BENCH_HISTORY.jsonl"


def history_path(root: str) -> str:
    return os.path.join(root, HISTORY_FILE)


def git_sha(root: Optional[str] = None) -> str:
    """Short commit sha of ``root`` (cwd when None); "unknown" outside
    git / without the binary — history must never sink a bench run."""
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             cwd=root or ".", capture_output=True,
                             text=True, timeout=10)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except Exception:
        return "unknown"


def payload_variant(payload: dict) -> str:
    cfg = payload.get("cfg") or {}
    return "smoke" if cfg.get("smoke") else "full"


def read_history(path: str) -> List[Dict[str, object]]:
    """All records, file order (appends only, so file order = time
    order).  Skips corrupt/partial lines instead of failing the gate."""
    out: List[Dict[str, object]] = []
    if not os.path.exists(path):
        return out
    with open(path, "r") as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue
            if isinstance(rec, dict) and "metric" in rec:
                out.append(rec)
    return out


def _next_run(records: List[Dict[str, object]], bench: str,
              variant: str) -> int:
    runs = [int(r.get("run", 0)) for r in records
            if r.get("bench") == bench and r.get("variant") == variant]
    return (max(runs) + 1) if runs else 0


def history_records(payload: dict, run: int = 0,
                    sha: str = "unknown") -> List[Dict[str, object]]:
    """Flatten one bench payload into its history lines (pure)."""
    bench = payload.get("name", "?")
    variant = payload_variant(payload)
    return [{"bench": bench, "variant": variant, "run": int(run),
             "git_sha": sha, **m} for m in extract_metrics(payload)]


def append_history(payload: dict, path: str,
                   sha: Optional[str] = None) -> List[Dict[str, object]]:
    """Append one bench run's metric records to the trajectory file.

    Reads the existing file only to number the run; the write itself is
    a pure append.  Returns the records written.
    """
    if sha is None:
        sha = git_sha(os.path.dirname(path) or ".")
    existing = read_history(path)
    recs = history_records(
        payload, run=_next_run(existing, payload.get("name", "?"),
                               payload_variant(payload)), sha=sha)
    with open(path, "a") as fh:
        for r in recs:
            fh.write(json.dumps(r, sort_keys=True) + "\n")
    return recs
