"""Bench-payload metric schema: flatten + classify.

Every ``BENCH_<name>.json`` payload is a nested dict of measurement
groups; the comparator needs flat ``metric → scalar`` pairs whose paths
stay STABLE across runs.  :func:`extract_metrics` walks the payload:

* dict keys join with ``.`` (``telemetry.mean_pcg_iters_per_solve``);
* lists of dicts key each element by its discriminator field —
  ``topology`` / ``family`` / ``backend`` / ``offered_rate`` / ... —
  giving ``topologies[grid].adaptive_fused.s_per_solve`` instead of a
  positional index that would reshuffle whenever a bench adds a case;
* ``cfg`` / ``obs`` / ``name`` / ``derived`` subtrees and raw sample
  lists are skipped (configuration echo and unbounded detail, not
  comparable measurements);
* bools become 0/1 so ok-flags (``quality_ok``, ``parity_ok``,
  ``zero_extra_collectives``) gate generically: any True→False flip is
  a regression.

:func:`classify` maps a metric path to ``(kind, direction)``:

    kind        direction   default rel. threshold
    time        lower       0.35   (wall-clock: noisy on shared hosts)
    throughput  higher      0.30
    ratio       higher      0.30   (speedups: a ratio of two walls)
    count       lower|higher 0.05  (iteration counts: deterministic)
    quality     equal|lower 2e-3   (cut values: the benches' own
                                    quality_rtol discipline — voltages
                                    agree per seed, rounding can flip a
                                    borderline node across hosts)
    bool        higher      0      (any flip fires)
    info        —           ∞      (tracked, never gated)

Direction is what "worse" means: a LOWER-is-better latency regresses
upward, a HIGHER-is-better throughput regresses downward, an
EQUAL-direction cut value regresses in either direction.  Unrecognized
metrics default to ``info`` — the gate only ever fires on explicitly
classified measurements.
"""
from __future__ import annotations

import re
from typing import Dict, Iterator, List, Tuple

__all__ = ["extract_metrics", "classify", "KIND_RTOL", "KINDS"]

# discriminator fields tried IN ORDER to key list elements stably
_DISCRIMINATORS = ("topology", "family", "backend", "name", "kind",
                   "offered_rate", "side", "phase")
# subtrees that are configuration/observability echo, not measurements
_SKIP_KEYS = {"cfg", "obs", "name", "derived"}

KINDS = ("time", "throughput", "ratio", "count", "quality", "bool", "info")

#: default relative thresholds per kind (fraction of |baseline median|);
#: the comparator takes max(rtol·|median|, z·1.4826·MAD) so a noisy
#: baseline widens its own gate
KIND_RTOL: Dict[str, float] = {
    "time": 0.35,
    "throughput": 0.30,
    "ratio": 0.30,
    "count": 0.05,
    "quality": 2e-3,
    "bool": 0.0,
    "info": float("inf"),
}

# (regex on the FULL path, kind, direction) — first match wins.  Info
# rules come first so config echoes like max_wait_ms never match the
# *_ms time rule.
_RULES: List[Tuple[str, str, str]] = [
    # -- config echo / context: tracked but never gated ---------------------
    (r"(^|\.)(n|m|side|solves|n_solves|n_waves|batches|base|repeat)$",
     "info", "higher"),
    (r"(^|\.)(max_batch|max_wait_ms|n_requests|n_topos|n_workers)$",
     "info", "higher"),
    (r"(^|\.)(n_pairs|pair_solves|sampled_pairs|refine_changed_edges)$",
     "info", "higher"),
    (r"(^|\.)(parity_rtol|offered_rate|reference_rate)$", "info", "higher"),
    (r"by_worker|flush_reasons|rule_stats|cache\.", "info", "higher"),
    (r"(^|\.)(utilization|mean_batch_size|early_exit_rate)$",
     "info", "higher"),
    (r"share_of_total$|overhead_frac$", "info", "lower"),
    (r"(^|\.)flops$|hbm_bytes$|while_trip_scale$|roofline", "info", "higher"),
    # -- deterministic counts ----------------------------------------------
    (r"pcg_iters|pcg_total|irls_iters|irls_executed", "count", "lower"),
    (r"(^|\.)(kernel_n|kernel_m)$", "count", "lower"),
    (r"(node|edge|iter)_reduction$", "count", "higher"),
    # -- solution quality ---------------------------------------------------
    (r"rel_diff$|rel_gap$|max_rel", "quality", "lower"),
    (r"(^|\.)(cut_value|cut_plain|cut_presolve|cut_adaptive|cut_fixed|"
     r"oracle_cut|global_min_cut_exact|global_min_cut_irls)$",
     "quality", "equal"),
    # -- throughput / ratios ------------------------------------------------
    (r"per_sec$|_gflops$|_gbps$", "throughput", "higher"),
    (r"speedup|slo_attainment", "ratio", "higher"),
    # -- wall-clock ---------------------------------------------------------
    (r"(_|^)(us|ms|s)$|_us_|us_per_call|s_per_solve", "time", "lower"),
    (r"p50|p99|latency|_wall$|seconds", "time", "lower"),
]
_COMPILED = [(re.compile(pat), kind, direction)
             for pat, kind, direction in _RULES]


def classify(path: str) -> Tuple[str, str]:
    """Metric path → ``(kind, direction)``; unrecognized → ``("info", ...)``.

    Bool-valued metrics are detected by VALUE in :func:`extract_metrics`,
    not by name — this function only sees the path.
    """
    leaf = path.rsplit("]", 1)[-1].lstrip(".")
    for rx, kind, direction in _COMPILED:
        if rx.search(leaf) or rx.search(path):
            return kind, direction
    return "info", "higher"


def _element_key(elem: dict, index: int) -> str:
    for d in _DISCRIMINATORS:
        if d in elem and isinstance(elem[d], (str, int, float)):
            v = elem[d]
            if isinstance(v, float):
                v = f"{v:g}"
            return str(v)
    return str(index)


def _walk(obj, path: str) -> Iterator[Tuple[str, float, bool]]:
    if isinstance(obj, dict):
        for k in sorted(obj):
            if not path and k in _SKIP_KEYS:
                continue
            sub = f"{path}.{k}" if path else str(k)
            yield from _walk(obj[k], sub)
    elif isinstance(obj, (list, tuple)):
        if obj and all(isinstance(e, dict) for e in obj):
            for i, e in enumerate(obj):
                yield from _walk(e, f"{path}[{_element_key(e, i)}]")
        # lists of scalars are raw samples (latency traces, batch sizes):
        # unbounded, order-dependent — not comparable metrics
    elif isinstance(obj, bool):
        yield path, float(obj), True
    elif isinstance(obj, (int, float)) and obj == obj:   # finite or inf, not NaN
        yield path, float(obj), False


def extract_metrics(payload: dict) -> List[Dict[str, object]]:
    """Flatten a bench payload into classified scalar metrics.

    Returns ``[{"metric", "value", "kind", "direction"}, ...]`` sorted by
    metric path.  NaN values (sanitized to null in the written payload
    anyway) are dropped; bools are emitted as 0/1 with kind ``bool``.
    """
    out = []
    for path, value, is_bool in _walk(payload, ""):
        if is_bool:
            kind, direction = "bool", "higher"
        else:
            kind, direction = classify(path)
        out.append({"metric": path, "value": value,
                    "kind": kind, "direction": direction})
    out.sort(key=lambda r: r["metric"])
    return out
