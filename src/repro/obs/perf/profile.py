"""Continuous profiling: device-side FLOP/byte estimates per solve.

``compiled.cost_analysis()`` is the authoritative XLA flop/byte count —
but it counts every while-loop body exactly ONCE, so a scanned IRLS
program (T-trip ``lax.scan``) under-reports by the trip count.
``launch.hlo_analysis.analyze`` already propagates ``known_trip_count``
multipliers down the HLO call graph; :func:`compiled_costs` reuses that
correction as a RATIO — walker-with-trips over walker-body-once —
applied to XLA's own numbers:

    flops ≈ cost_analysis.flops × (analyze(hlo).flops /
                                   analyze(hlo minus trip counts).flops)

Dynamic-trip whiles (the masked PCG inner loop, host early-exit loops)
carry no ``known_trip_count`` and stay counted once — the estimate is a
LOWER BOUND under adaptive schedules, which is the honest direction for
an achieved-GFLOP/s figure.

Profiling pays one extra AOT ``lower().compile()`` per compiled-program
cache key (≈0.2–1 s), so it is OFF for plain solves and ON when the
obs tracing layer is enabled or ``REPRO_PROFILE=1`` — the bench harness
and the ``bench_diff`` CLI set the env var, so every recorded bench
payload carries achieved GFLOP/s without taxing the unit-test hot path.
"""
from __future__ import annotations

import os
import re
from typing import Any, Dict, Optional

__all__ = ["default_enabled", "compiled_costs", "program_costs",
           "per_solve_cost", "PROFILE_ENV"]

PROFILE_ENV = "REPRO_PROFILE"

_TRIP_MASK = re.compile(r"known_trip_count")


def default_enabled() -> bool:
    """Profile by default?  ``REPRO_PROFILE`` (1/0) wins; otherwise
    follow the tracing switch — a traced run wants the device-side
    counters, an untraced unit test wants the compile time back."""
    env = os.environ.get(PROFILE_ENV, "").strip().lower()
    if env in ("1", "true", "on", "yes"):
        return True
    if env in ("0", "false", "off", "no"):
        return False
    from repro.obs import trace
    return trace.enabled()


def compiled_costs(compiled) -> Dict[str, float]:
    """FLOP/byte estimates of one compiled XLA program (per execution).

    ``compiled`` — a ``jax.stages.Compiled`` (from ``.lower().compile()``).
    Returns ``{"flops", "hbm_bytes", "collective_bytes",
    "cost_analysis_flops", "while_trip_scale"}`` — see module docstring
    for the trip-count correction.
    """
    text = compiled.as_text()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):               # jax<0.5 returns [dict]
        ca = ca[0] if ca else {}
    raw_flops = float(ca.get("flops", 0.0) or 0.0)
    raw_bytes = float(ca.get("bytes accessed", 0.0) or 0.0)

    from repro.launch.hlo_analysis import analyze
    with_trips = analyze(text)
    body_once = analyze(_TRIP_MASK.sub("masked_trip_count", text))
    fscale = (with_trips.flops / body_once.flops
              if body_once.flops > 0 else 1.0)
    bscale = (with_trips.hbm_bytes / body_once.hbm_bytes
              if body_once.hbm_bytes > 0 else 1.0)
    flops = raw_flops * fscale if raw_flops > 0 else with_trips.flops
    hbm = raw_bytes * bscale if raw_bytes > 0 else with_trips.hbm_bytes
    return {"flops": float(flops), "hbm_bytes": float(hbm),
            "collective_bytes": float(with_trips.collective_bytes),
            "cost_analysis_flops": raw_flops,
            "while_trip_scale": float(fscale)}


def program_costs(jitted, *example_args, **example_kwargs
                  ) -> Optional[Dict[str, float]]:
    """AOT lower + compile ``jitted`` at the example arguments (concrete
    arrays or ``ShapeDtypeStruct``s) and extract its costs.  Returns
    None instead of raising — profiling must never sink a solve."""
    try:
        compiled = jitted.lower(*example_args, **example_kwargs).compile()
        return compiled_costs(compiled)
    except Exception:
        return None


def per_solve_cost(cost: Optional[Dict[str, float]], seconds: float,
                   calls: float = 1.0) -> Optional[Dict[str, Any]]:
    """Scale a per-execution cost record to one solve and derive rates.

    ``calls`` — program executions this solve ran (the host backend runs
    its compiled step once per IRLS iteration; scanned/sharded programs
    are whole-solve, calls=1).  ``seconds`` — the solve's IRLS wall.
    Rates divide by wall seconds; the roofline fraction compares the
    wall against the time the TPU-v5e roofline model says the program's
    flops/bytes NEED (``hlo_analysis.roofline_terms`` constants) — on a
    CPU host it is tiny, on the target mesh it approaches 1.
    """
    if cost is None:
        return None
    from repro.launch.hlo_analysis import HBM_BW, ICI_BW, PEAK_FLOPS
    flops = cost["flops"] * calls
    hbm = cost["hbm_bytes"] * calls
    coll = cost.get("collective_bytes", 0.0) * calls
    out: Dict[str, Any] = {
        "flops": flops, "hbm_bytes": hbm, "collective_bytes": coll,
        "program_calls": float(calls),
        "while_trip_scale": cost.get("while_trip_scale", 1.0),
    }
    if seconds and seconds > 0:
        out["achieved_gflops"] = flops / seconds / 1e9
        out["achieved_gbps"] = hbm / seconds / 1e9
        t_roof = max(flops / PEAK_FLOPS, hbm / HBM_BW, coll / ICI_BW)
        out["roofline_fraction"] = t_roof / seconds
    return out
