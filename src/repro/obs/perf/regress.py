"""Noise-aware bench comparator: baseline = median + MAD, direction-aware.

For each metric the baseline is the last K matching ``(bench, metric,
variant)`` entries of the trajectory (``history.py``).  The decision
threshold is

    max(rtol_kind · |median|,  z · 1.4826 · MAD,  atol_kind)

so a deterministic metric (MAD = 0) gates at the kind's relative
tolerance while a noisy one widens its own gate — 1.4826·MAD estimates
the standard deviation robustly (no single outlier run can poison the
baseline the way a mean/stddev fit would), and z = 4 puts the false-
positive rate per metric in the 1e-4 range under roughly normal noise.
Classification is direction-aware: a lower-is-better latency regresses
UPWARD, a higher-is-better throughput regresses DOWNWARD, an
equal-direction cut value regresses either way.  Metrics with no
baseline classify ``new``; ``info`` metrics always classify ``flat``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from .history import payload_variant
from .schema import KIND_RTOL, extract_metrics

__all__ = ["Verdict", "compare_payload", "gate", "render_table",
           "MAD_SIGMA", "DEFAULT_Z"]

MAD_SIGMA = 1.4826        # MAD → sigma under normal noise
DEFAULT_Z = 4.0
DEFAULT_K = 8

#: absolute floors per kind: a bool flip is |Δ| = 1 (floor 0.5); quality
#: metrics compare near-zero rel-diffs (floor 1e-9); everything else
#: relies on the relative term
_KIND_ATOL = {"bool": 0.5, "quality": 1e-9}
GATEABLE_KINDS = ("time", "throughput", "ratio", "count", "quality", "bool")


@dataclass
class Verdict:
    bench: str
    metric: str
    kind: str
    direction: str
    classification: str          # regressed | improved | flat | new
    current: float
    baseline_median: Optional[float]
    baseline_mad: Optional[float]
    n_baseline: int
    threshold: float
    delta: float                 # current - baseline_median (0.0 when new)

    @property
    def delta_rel(self) -> float:
        if not self.baseline_median:
            return float("nan") if self.classification == "new" else 0.0
        return self.delta / abs(self.baseline_median)


def _median(xs: Sequence[float]) -> float:
    s = sorted(xs)
    k = len(s)
    mid = k // 2
    return s[mid] if k % 2 else 0.5 * (s[mid - 1] + s[mid])


def classify_value(bench: str, metric: str, kind: str, direction: str,
                   baseline: Sequence[float], current: float,
                   rtol: Optional[float] = None,
                   z: float = DEFAULT_Z) -> Verdict:
    if not baseline:
        return Verdict(bench, metric, kind, direction, "new", current,
                       None, None, 0, float("inf"), 0.0)
    med = _median(baseline)
    mad = _median([abs(b - med) for b in baseline])
    if rtol is None:
        rtol = KIND_RTOL.get(kind, float("inf"))
    thresh = max(rtol * abs(med), z * MAD_SIGMA * mad,
                 _KIND_ATOL.get(kind, 0.0))
    delta = current - med
    if kind == "info" or thresh == float("inf"):
        cls = "flat"
    elif direction == "lower":
        cls = ("regressed" if delta > thresh
               else "improved" if delta < -thresh else "flat")
    elif direction == "higher":
        cls = ("regressed" if delta < -thresh
               else "improved" if delta > thresh else "flat")
    else:                                      # equal: any drift is bad
        cls = "regressed" if abs(delta) > thresh else "flat"
    return Verdict(bench, metric, kind, direction, cls, current, med, mad,
                   len(baseline), thresh, delta)


def compare_payload(payload: dict, history: List[Dict[str, object]],
                    k: int = DEFAULT_K,
                    rtols: Optional[Dict[str, float]] = None,
                    z: float = DEFAULT_Z) -> List[Verdict]:
    """Classify every metric of ``payload`` against the trajectory.

    ``history`` should be the records read BEFORE this payload's own run
    was appended (the CLI snapshots the file first), so the baseline
    never includes the measurement under test.
    """
    bench = payload.get("name", "?")
    variant = payload_variant(payload)
    by_metric: Dict[str, List[float]] = {}
    for r in history:
        if r.get("bench") == bench and r.get("variant") == variant:
            try:
                by_metric.setdefault(str(r["metric"]), []).append(
                    float(r["value"]))     # type: ignore[arg-type]
            except (TypeError, ValueError):
                continue
    out = []
    for m in extract_metrics(payload):
        kind = str(m["kind"])
        rtol = (rtols or {}).get(kind)
        baseline = by_metric.get(str(m["metric"]), [])[-k:]
        out.append(classify_value(bench, str(m["metric"]), kind,
                                  str(m["direction"]), baseline,
                                  float(m["value"]), rtol=rtol, z=z))
    return out


def gate(verdicts: Sequence[Verdict],
         kinds: Optional[Sequence[str]] = None) -> List[Verdict]:
    """The regressions that should fail the run, restricted to ``kinds``
    (default: every gateable kind — pass ``("count", "quality", "bool")``
    for machine-independent CI gating, where wall-clock baselines
    recorded on one host don't transfer to another)."""
    kinds = tuple(kinds) if kinds is not None else GATEABLE_KINDS
    return [v for v in verdicts
            if v.classification == "regressed" and v.kind in kinds]


_ORDER = {"regressed": 0, "improved": 1, "new": 2, "flat": 3}


def render_table(verdicts: Sequence[Verdict], show: str = "changed",
                 top: int = 40) -> str:
    """Text table, regressions first.

    show — "changed": regressed/improved/new only; "all": everything
    except info; "gated": regressed only.
    """
    if show == "gated":
        rows = [v for v in verdicts if v.classification == "regressed"]
    elif show == "all":
        rows = [v for v in verdicts if v.kind != "info"]
    else:
        rows = [v for v in verdicts
                if v.classification in ("regressed", "improved", "new")
                and v.kind != "info"]
    rows = sorted(rows, key=lambda v: (_ORDER[v.classification],
                                       -abs(v.delta_rel or 0.0), v.metric))
    n_reg = sum(1 for v in verdicts if v.classification == "regressed")
    n_imp = sum(1 for v in verdicts if v.classification == "improved")
    bench = verdicts[0].bench if verdicts else "?"
    head = (f"{bench}: {len(verdicts)} metrics — {n_reg} regressed, "
            f"{n_imp} improved")
    if not rows:
        return head + " (nothing to show)"
    lines = [head,
             f"  {'metric':<58} {'kind':<10} {'baseline':>12} "
             f"{'current':>12} {'Δ':>8}  class"]
    for v in rows[:top]:
        name = v.metric if len(v.metric) <= 58 else "..." + v.metric[-55:]
        base = ("—" if v.baseline_median is None
                else f"{v.baseline_median:.6g}")
        dr = v.delta_rel
        delta = ("" if v.classification == "new" or dr != dr
                 else f"{dr:+.1%}")
        lines.append(f"  {name:<58} {v.kind:<10} {base:>12} "
                     f"{v.current:>12.6g} {delta:>8}  {v.classification}")
    if len(rows) > top:
        lines.append(f"  ... {len(rows) - top} more")
    return "\n".join(lines)
