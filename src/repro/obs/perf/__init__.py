"""Performance trajectory + regression detection (the perf sentinel).

Layered on the PR 7 obs stack, three pieces:

``schema``   — flatten a ``BENCH_<name>.json`` payload into comparable
               scalar metrics with stable dotted paths, each classified
               into a (kind, direction) pair (time/lower, throughput/
               higher, count/lower, quality/equal, ...).
``history``  — the append-only ``BENCH_HISTORY.jsonl`` trajectory store
               ``benchmarks.run.write_payloads`` feeds: one flattened
               ``{bench, variant, run, git_sha, metric, value}`` record
               per metric per bench run, committed alongside the
               ``BENCH_*.json`` snapshots so the repo carries its own
               noise baseline.
``regress``  — the noise-aware comparator: per-metric baseline =
               median + MAD over the last K matching-variant history
               entries, direction-aware classification into
               regressed / improved / flat / new.
``profile``  — continuous profiling: ``compiled.cost_analysis()``
               FLOP/byte estimates with ``launch.hlo_analysis``'s
               while-body-once trip-count correction, attached to every
               cached compiled program by ``core.session`` so
               ``SolveResult.telemetry`` reports achieved GFLOP/s and
               roofline fraction per solve.

CLI: ``python -m repro.launch.bench_diff`` (record → diff → gate).
"""
from .history import (HISTORY_FILE, append_history, git_sha, history_path,
                      history_records, read_history)
from .profile import (compiled_costs, default_enabled, per_solve_cost,
                      program_costs)
from .regress import Verdict, compare_payload, gate, render_table
from .schema import classify, extract_metrics

__all__ = [
    "HISTORY_FILE", "append_history", "git_sha", "history_path",
    "history_records", "read_history",
    "compiled_costs", "default_enabled", "per_solve_cost", "program_costs",
    "Verdict", "compare_payload", "gate", "render_table",
    "classify", "extract_metrics",
]
