"""Structured span tracer — the timing substrate every subsystem shares.

One global :class:`Tracer` (off by default; ``configure(enabled=True)``
turns it on) records nested, thread-aware spans:

    from repro.obs import trace

    with trace.span("irls.solve", topo=key, backend="scanned") as sp:
        v = run(...)
        sp.fence(v)                    # block_until_ready: device work is
        sp.set(pcg_iters=int(it))      # attributed to the span that ran it

Design constraints (this is hot-path adjacent code):

* **Disabled means free.**  ``span()`` returns a shared no-op context
  manager when tracing is off — one attribute read and one branch, no
  allocation, no lock.  The serving engine and the solver session keep
  their instrumentation unconditionally in place because of this.
* **Nesting is implicit.**  A thread-local stack supplies each span's
  parent, so the engine worker thread, caller threads and test threads
  each get their own well-formed span tree; spans survive exceptions
  (``__exit__`` records the error type and still closes the span).
* **Two sinks.**  Every finished span lands in an in-memory ring
  (bounded ``deque`` — a long-running server cannot leak) and, when a
  JSONL path is configured, as one JSON object per line (the format the
  ``repro.launch.obs`` dashboard tails; schema in docs/API.md).
* **Device attribution is explicit.**  JAX dispatch is async: a span
  that merely *launched* device work closes before the work ran.
  ``sp.fence(x)`` calls ``jax.block_until_ready`` so the wall time lands
  in the span that did the launching (skipped when tracing is off — the
  fence must never change disabled-mode behavior).
* **Profiler passthrough.**  ``configure(profiler=True)`` additionally
  wraps each span in ``jax.profiler.TraceAnnotation`` so the same names
  show up on the device timeline in a real profiler trace.
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional

__all__ = ["Tracer", "SpanRecord", "get_tracer", "configure", "enabled",
           "span", "event", "spans", "clear", "fence"]


class SpanRecord:
    """One finished span (plain attributes; ``to_dict`` for the sinks)."""

    __slots__ = ("name", "span_id", "parent_id", "thread", "t0", "t1",
                 "attrs", "error")

    def __init__(self, name: str, span_id: int, parent_id: Optional[int],
                 thread: str, t0: float, t1: float,
                 attrs: Dict[str, Any], error: Optional[str]):
        self.name = name
        self.span_id = span_id
        self.parent_id = parent_id
        self.thread = thread
        self.t0 = t0
        self.t1 = t1
        self.attrs = attrs
        self.error = error

    @property
    def dur_s(self) -> float:
        return self.t1 - self.t0

    def to_dict(self) -> Dict[str, Any]:
        d = {"name": self.name, "span_id": self.span_id,
             "parent_id": self.parent_id, "thread": self.thread,
             "t0": self.t0, "t1": self.t1, "dur_s": self.dur_s}
        if self.attrs:
            d["attrs"] = self.attrs
        if self.error is not None:
            d["error"] = self.error
        return d


class _NoopSpan:
    """Shared do-nothing span: the entire disabled-mode cost."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "_NoopSpan":
        return self

    def fence(self, *vals):
        # no block_until_ready when tracing is off: the fence exists for
        # attribution, and disabled tracing must not change async dispatch
        return vals[0] if len(vals) == 1 else vals


_NOOP = _NoopSpan()


class _Span:
    """Live span handle (context manager)."""

    __slots__ = ("_tracer", "name", "span_id", "parent_id", "t0", "attrs",
                 "_annotation")

    def __init__(self, tracer: "Tracer", name: str, parent_id: Optional[int],
                 attrs: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.span_id = tracer._next_id()
        self.parent_id = parent_id
        self.attrs = attrs
        self.t0 = 0.0
        self._annotation = None

    def set(self, **attrs) -> "_Span":
        self.attrs.update(attrs)
        return self

    def fence(self, *vals):
        """Block until ``vals`` are device-ready; time lands in this span."""
        import jax
        for v in vals:
            jax.block_until_ready(v)
        return vals[0] if len(vals) == 1 else vals

    def __enter__(self) -> "_Span":
        tr = self._tracer
        if tr._profiler:
            try:
                import jax
                self._annotation = jax.profiler.TraceAnnotation(self.name)
                self._annotation.__enter__()
            except Exception:
                self._annotation = None
        tr._stack().append(self.span_id)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        t1 = time.perf_counter()
        tr = self._tracer
        stack = tr._stack()
        # tolerate a corrupted stack rather than masking the caller's error
        if stack and stack[-1] == self.span_id:
            stack.pop()
        elif self.span_id in stack:
            del stack[stack.index(self.span_id):]
        if self._annotation is not None:
            try:
                self._annotation.__exit__(exc_type, exc, tb)
            except Exception:
                pass
        err = None if exc_type is None else exc_type.__name__
        tr._emit(SpanRecord(self.name, self.span_id, self.parent_id,
                            threading.current_thread().name, self.t0, t1,
                            self.attrs, err))
        return False


class Tracer:
    """Thread-safe span recorder: ring buffer + optional JSONL sink."""

    def __init__(self, ring: int = 8192):
        self._enabled = False
        self._profiler = False
        self._ring: "deque[SpanRecord]" = deque(maxlen=ring)
        self._lock = threading.Lock()
        self._local = threading.local()
        self._id = 0
        self._jsonl_path: Optional[str] = None
        self._jsonl_file = None

    # -- configuration ---------------------------------------------------------
    def configure(self, enabled: Optional[bool] = None,
                  ring: Optional[int] = None,
                  jsonl: Optional[str] = None,
                  profiler: Optional[bool] = None) -> "Tracer":
        """Reconfigure in place; only the arguments given change.

        ``jsonl`` — path to append finished spans to (one JSON object per
        line), or ``""`` to close the current sink.  Configuring a sink
        implies ``enabled=True`` unless ``enabled=False`` is passed
        explicitly.
        """
        with self._lock:
            if ring is not None:
                self._ring = deque(self._ring, maxlen=ring)
            if jsonl is not None:
                if self._jsonl_file is not None:
                    self._jsonl_file.close()
                    self._jsonl_file = None
                self._jsonl_path = jsonl or None
                if self._jsonl_path:
                    os.makedirs(os.path.dirname(
                        os.path.abspath(self._jsonl_path)), exist_ok=True)
                    self._jsonl_file = open(self._jsonl_path, "a",
                                            buffering=1)
                    if enabled is None:
                        enabled = True
            if profiler is not None:
                self._profiler = profiler
            if enabled is not None:
                self._enabled = enabled
        return self

    @property
    def enabled(self) -> bool:
        return self._enabled

    @property
    def jsonl_path(self) -> Optional[str]:
        return self._jsonl_path

    # -- recording -------------------------------------------------------------
    def span(self, name: str, **attrs):
        """Context manager for one timed span (no-op when disabled)."""
        if not self._enabled:
            return _NOOP
        stack = self._stack()
        parent = stack[-1] if stack else None
        return _Span(self, name, parent, attrs)

    def event(self, name: str, **attrs) -> None:
        """Zero-duration span (structured point event, e.g. a warning)."""
        if not self._enabled:
            return
        stack = self._stack()
        parent = stack[-1] if stack else None
        now = time.perf_counter()
        self._emit(SpanRecord(name, self._next_id(), parent,
                              threading.current_thread().name, now, now,
                              attrs, None))

    # -- reading ---------------------------------------------------------------
    def spans(self) -> List[SpanRecord]:
        with self._lock:
            return list(self._ring)

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()

    # -- internals -------------------------------------------------------------
    def _stack(self) -> List[int]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _next_id(self) -> int:
        with self._lock:
            self._id += 1
            return self._id

    def _emit(self, rec: SpanRecord) -> None:
        with self._lock:
            self._ring.append(rec)
            if self._jsonl_file is not None:
                try:
                    self._jsonl_file.write(
                        json.dumps(rec.to_dict(), default=str) + "\n")
                except (ValueError, OSError):
                    pass       # sink closed mid-shutdown; the ring still has it


# -- module-level default tracer (what all in-repo instrumentation uses) -------
_TRACER = Tracer()


def get_tracer() -> Tracer:
    return _TRACER


def configure(**kwargs) -> Tracer:
    return _TRACER.configure(**kwargs)


def enabled() -> bool:
    return _TRACER.enabled


def span(name: str, **attrs):
    return _TRACER.span(name, **attrs)


def event(name: str, **attrs) -> None:
    _TRACER.event(name, **attrs)


def spans() -> List[SpanRecord]:
    return _TRACER.spans()


def clear() -> None:
    _TRACER.clear()


def fence(*vals):
    """Block until device-ready iff tracing is enabled (free otherwise)."""
    if _TRACER.enabled:
        import jax
        for v in vals:
            jax.block_until_ready(v)
    return vals[0] if len(vals) == 1 else vals
